//! Minimal JSON parser/emitter (no serde available offline).
//!
//! Covers the full JSON grammar we produce and consume: the artifact
//! manifest written by `python/compile/aot.py`, experiment configs, and
//! results files. Numbers parse to f64; integer accessors check exactness.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Emit compact JSON.
    pub fn emit(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building results/configs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            // Surrogate pairs unsupported (not produced by our writers).
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.req("a").as_arr().unwrap()[2].req("b").as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"num":3,"obj":{"k":true}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.emit()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn usize_accessor_strictness() {
        assert_eq!(Json::parse("5").unwrap().as_usize(), Some(5));
        assert_eq!(Json::parse("5.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-5").unwrap().as_usize(), None);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"archs":{"mlp":{"d":17098,"in_shape":[16,16,1],
            "params":[{"fan_in":256,"name":"dense0_w","offset":0,"shape":[256,64]}]}},
            "artifacts":{"smoke":{"file":"smoke.hlo.txt","inputs":[{"dtype":"float32","shape":[2,2]}]}},
            "eval_batch":256,"format":1,"train_batch":64}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.req("archs").req("mlp").req("d").as_usize(), Some(17098));
        assert_eq!(j.req("train_batch").as_usize(), Some(64));
    }
}
