//! Network and runtime configuration: the one resolution point for the
//! `BICOMPFL_TRANSPORT` / `BICOMPFL_FAULTS` / `BICOMPFL_THREADS`
//! environment variables, their CLI flags, and the `--topology net.toml`
//! peer-discovery file.
//!
//! ## Precedence
//!
//! One rule, applied per knob: **a CLI flag beats its environment variable,
//! which beats the built-in default.** Nothing merges — the winning source
//! supplies the whole value. [`NetConfig::from_env_and_args`] is the only
//! place this resolution happens; everything downstream takes the typed
//! result. Every parse failure is a [`TransportError::Config`] naming the
//! offending source — a typo must never silently select a default (the
//! PR 7 bugfix: an unrecognized `BICOMPFL_TRANSPORT` used to un-meter the
//! wire by falling back to `loopback`).
//!
//! ## Topology files
//!
//! `--topology net.toml` replaces positional address arguments for
//! multi-host runs. The format is a small TOML subset, parsed here with no
//! dependency (quoted strings, unsigned integers, `#` comments):
//!
//! ```toml
//! [federator]
//! listen = "127.0.0.1:7070"
//! cohort = 8              # optional: m-of-n partial participation
//!
//! [[client]]
//! id = 0
//! addr = "127.0.0.1:7070"
//!
//! [[client]]
//! id = 1
//! addr = "127.0.0.1:7070"
//! ```
//!
//! Validation is strict: `listen` is required, client ids must cover
//! `0..n` exactly (no gaps, no duplicates), every client needs an `addr`,
//! and `cohort` (when present) must lie in `1..=n`.

use std::path::Path;

use crate::transport::{FaultSpec, Result, TransportError, TransportKind};

/// The resolved network/runtime configuration (see the module docs for the
/// precedence rule).
#[derive(Clone, Debug, Default)]
pub struct NetConfig {
    /// The in-process transport backend (`BICOMPFL_TRANSPORT`).
    pub transport: TransportKind,
    /// Fault injection and tolerance (`--faults` / `BICOMPFL_FAULTS`);
    /// `None` when unset *or* when the spec parses to all-zero (a zero spec
    /// is the strict protocol, not a tolerant run with no faults).
    pub faults: Option<FaultSpec>,
    /// Worker-pool width (`BICOMPFL_THREADS`); `None` means one worker per
    /// available hardware thread.
    pub threads: Option<usize>,
    /// The `--topology` file, when given.
    pub topology: Option<Topology>,
}

impl NetConfig {
    /// Resolve the full network configuration from CLI flags and the
    /// environment. Per knob, **flag > env > default**:
    ///
    /// * `transport_flag` (else `BICOMPFL_TRANSPORT`, else `loopback`) —
    ///   parsed by [`TransportKind::parse`];
    /// * `faults_flag` (else `BICOMPFL_FAULTS`, else none) — parsed by
    ///   [`FaultSpec::parse`]; an all-zero spec resolves to `None`;
    /// * `BICOMPFL_THREADS` (no flag exists) via [`threads_from_env`];
    /// * `topology_path` is loaded and validated by [`Topology::load`].
    ///
    /// Any unparseable source is a [`TransportError::Config`] naming it.
    pub fn from_env_and_args(
        transport_flag: Option<&str>,
        faults_flag: Option<&str>,
        topology_path: Option<&Path>,
    ) -> Result<Self> {
        let transport = match transport_flag {
            Some(v) => TransportKind::parse(v)?,
            None => match std::env::var("BICOMPFL_TRANSPORT") {
                Ok(v) => TransportKind::parse(&v)?,
                Err(_) => TransportKind::default(),
            },
        };
        let faults = match faults_flag {
            Some(v) => Some(
                FaultSpec::parse(v)
                    .map_err(|why| TransportError::Config(format!("--faults: {why}")))?,
            ),
            None => FaultSpec::from_env()
                .map_err(|why| TransportError::Config(format!("BICOMPFL_FAULTS: {why}")))?,
        };
        let faults = faults.filter(|f| !f.is_none());
        let threads = threads_from_env()?;
        let topology = match topology_path {
            Some(path) => Some(Topology::load(path)?),
            None => None,
        };
        Ok(Self {
            transport,
            faults,
            threads,
            topology,
        })
    }
}

/// Parse `BICOMPFL_THREADS`: unset or empty is `None` (use hardware
/// parallelism), a positive integer is `Some(n)`, anything else is a typed
/// [`TransportError::Config`] — never a silent fallback.
pub fn threads_from_env() -> Result<Option<usize>> {
    match std::env::var("BICOMPFL_THREADS") {
        Ok(v) if v.trim().is_empty() => Ok(None),
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => Ok(Some(n)),
            _ => Err(TransportError::Config(format!(
                "BICOMPFL_THREADS={v:?}: expected a positive integer"
            ))),
        },
        Err(_) => Ok(None),
    }
}

/// One client entry of a [`Topology`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Peer {
    /// Client id; the file must cover `0..n` exactly.
    pub id: u64,
    /// The federator address this client dials (`host:port`).
    pub addr: String,
}

/// A validated `--topology net.toml`: where the federator listens, where
/// each client connects, and the optional cohort size for partial
/// participation. See the module docs for the file format.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    /// The federator's listen address (`host:port`; port `0` = ephemeral).
    pub listen: String,
    /// Optional m-of-n cohort size (validated against `1..=n`).
    pub cohort: Option<usize>,
    /// The client entries, sorted by id (ids cover `0..n` exactly).
    pub clients: Vec<Peer>,
}

/// Drop a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_quote = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_quote = !in_quote,
            '#' if !in_quote => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse a quoted TOML string (no escapes — addresses never need them).
fn toml_str(v: &str) -> std::result::Result<String, String> {
    let inner = v
        .strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .ok_or_else(|| format!("expected a quoted string, got {v}"))?;
    if inner.contains('"') {
        return Err(format!("escapes/embedded quotes are not supported: {v}"));
    }
    Ok(inner.to_string())
}

/// Parse an unsigned TOML integer.
fn toml_int(v: &str) -> std::result::Result<u64, String> {
    v.parse::<u64>()
        .map_err(|_| format!("expected an unsigned integer, got {v}"))
}

/// Which table the parser is inside.
enum Section {
    Preamble,
    Federator,
    Client,
}

/// A client entry mid-parse (fields land one line at a time).
#[derive(Default)]
struct PeerDraft {
    id: Option<u64>,
    addr: Option<String>,
}

impl Topology {
    /// Read and parse `path`; I/O failures and format violations are both
    /// typed [`TransportError::Config`]s naming the file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| TransportError::Config(format!("topology {}: {e}", path.display())))?;
        Self::parse(&text)
            .map_err(|e| TransportError::Config(format!("topology {}: {e}", path.display())))
    }

    /// Parse and validate topology text (the testable core of
    /// [`Topology::load`]). Errors name the offending line.
    pub fn parse(text: &str) -> std::result::Result<Self, String> {
        let mut listen: Option<String> = None;
        let mut cohort: Option<usize> = None;
        let mut drafts: Vec<PeerDraft> = Vec::new();
        let mut section = Section::Preamble;
        for (idx, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let lineno = idx + 1;
            match line {
                "[federator]" => {
                    section = Section::Federator;
                    continue;
                }
                "[[client]]" => {
                    section = Section::Client;
                    drafts.push(PeerDraft::default());
                    continue;
                }
                _ if line.starts_with('[') => {
                    return Err(format!("line {lineno}: unknown section {line}"));
                }
                _ => {}
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {lineno}: expected `key = value`, got {line:?}"))?;
            let (key, value) = (key.trim(), value.trim());
            let at = |why: String| format!("line {lineno}: {why}");
            match section {
                Section::Preamble => {
                    return Err(at(format!("key {key:?} outside any section")));
                }
                Section::Federator => match key {
                    "listen" => listen = Some(toml_str(value).map_err(at)?),
                    "cohort" => cohort = Some(toml_int(value).map_err(at)? as usize),
                    other => return Err(at(format!("unknown [federator] key {other:?}"))),
                },
                Section::Client => {
                    let draft = drafts.last_mut().expect("Client section pushed a draft");
                    match key {
                        "id" => draft.id = Some(toml_int(value).map_err(at)?),
                        "addr" => draft.addr = Some(toml_str(value).map_err(at)?),
                        other => return Err(at(format!("unknown [[client]] key {other:?}"))),
                    }
                }
            }
        }

        let listen = listen.ok_or("missing [federator] listen address")?;
        if drafts.is_empty() {
            return Err("no [[client]] entries".into());
        }
        let n = drafts.len();
        let mut clients = Vec::with_capacity(n);
        for (k, draft) in drafts.into_iter().enumerate() {
            let id = draft.id.ok_or(format!("client entry {k} is missing `id`"))?;
            let addr = draft
                .addr
                .ok_or(format!("client entry {k} (id {id}) is missing `addr`"))?;
            clients.push(Peer { id, addr });
        }
        clients.sort_by_key(|p| p.id);
        for (k, peer) in clients.iter().enumerate() {
            if peer.id != k as u64 {
                return Err(format!(
                    "client ids must cover 0..{n} exactly; got {:?}",
                    clients.iter().map(|p| p.id).collect::<Vec<_>>()
                ));
            }
        }
        if let Some(m) = cohort {
            if m == 0 || m > n {
                return Err(format!("cohort = {m} out of range 1..={n}"));
            }
        }
        Ok(Self {
            listen,
            cohort,
            clients,
        })
    }

    /// The number of clients.
    pub fn n(&self) -> usize {
        self.clients.len()
    }

    /// The federator address client `id` dials, if the id is in range.
    pub fn addr_of(&self, id: u64) -> Option<&str> {
        self.clients.get(id as usize).map(|p| p.addr.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
# A two-client loopback topology.
[federator]
listen = "127.0.0.1:0"   # ephemeral port
cohort = 2

[[client]]
id = 1                   # order in the file does not matter
addr = "127.0.0.1:7070"

[[client]]
id = 0
addr = "127.0.0.1:7070"
"#;

    #[test]
    fn parses_the_documented_example() {
        let topo = Topology::parse(EXAMPLE).unwrap();
        assert_eq!(topo.listen, "127.0.0.1:0");
        assert_eq!(topo.cohort, Some(2));
        assert_eq!(topo.n(), 2);
        // Entries come back sorted by id regardless of file order.
        assert_eq!(topo.addr_of(0), Some("127.0.0.1:7070"));
        assert_eq!(topo.addr_of(1), Some("127.0.0.1:7070"));
        assert_eq!(topo.addr_of(2), None);
    }

    #[test]
    fn rejects_malformed_topologies() {
        // Missing listen.
        let err = Topology::parse("[[client]]\nid = 0\naddr = \"a:1\"").unwrap_err();
        assert!(err.contains("listen"), "{err}");
        // No clients.
        let err = Topology::parse("[federator]\nlisten = \"a:1\"").unwrap_err();
        assert!(err.contains("client"), "{err}");
        // Duplicate / gapped ids.
        for ids in [[0u64, 0], [0, 2]] {
            let text = format!(
                "[federator]\nlisten = \"a:1\"\n\
                 [[client]]\nid = {}\naddr = \"a:1\"\n\
                 [[client]]\nid = {}\naddr = \"a:1\"",
                ids[0], ids[1]
            );
            let err = Topology::parse(&text).unwrap_err();
            assert!(err.contains("cover 0..2"), "{err}");
        }
        // Missing addr.
        let text = "[federator]\nlisten = \"a:1\"\n[[client]]\nid = 0";
        let err = Topology::parse(text).unwrap_err();
        assert!(err.contains("addr"), "{err}");
        // Cohort out of range.
        let text = "[federator]\nlisten = \"a:1\"\ncohort = 3\n[[client]]\nid = 0\naddr = \"a:1\"";
        let err = Topology::parse(text).unwrap_err();
        assert!(err.contains("cohort"), "{err}");
        // Unquoted string, bad int, unknown key/section — all named by line.
        let cases = [
            ("[federator]\nlisten = a:1", "line 2"),
            ("[federator]\ncohort = x", "line 2"),
            ("[federator]\nport = 3", "unknown"),
            ("[server]", "unknown section"),
            ("listen = \"a:1\"", "outside"),
        ];
        for (text, want) in cases {
            let err = Topology::parse(text).unwrap_err();
            assert!(err.contains(want), "{text:?}: {err}");
        }
    }

    #[test]
    fn comments_respect_quotes() {
        assert_eq!(strip_comment("listen = \"a#b\" # trailing"), "listen = \"a#b\" ");
        assert_eq!(strip_comment("# whole line"), "");
        assert_eq!(strip_comment("id = 3"), "id = 3");
    }

    #[test]
    fn flags_beat_the_environment() {
        // Flag-supplied values must win regardless of what the ambient CI
        // environment sets (tests never mutate env vars — parallel tests
        // share the process environment).
        let cfg =
            NetConfig::from_env_and_args(Some("framed"), Some("deadline_ms=200;retries=2"), None)
                .unwrap();
        assert_eq!(cfg.transport, crate::transport::TransportKind::Framed);
        assert!(cfg.faults.is_some());
        assert!(cfg.topology.is_none());
        // A zero fault spec resolves to None — strict protocol.
        let cfg = NetConfig::from_env_and_args(Some("loopback"), Some("seed=7"), None).unwrap();
        assert!(cfg.faults.is_none());
        // Typos in flags are typed errors, not fallbacks.
        assert!(matches!(
            NetConfig::from_env_and_args(Some("bogus"), None, None),
            Err(TransportError::Config(_))
        ));
        assert!(matches!(
            NetConfig::from_env_and_args(None, Some("nonsense~~"), None),
            Err(TransportError::Config(_))
        ));
    }
}
