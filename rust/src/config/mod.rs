//! Experiment configuration and the preset catalogue mapping every paper
//! table / figure to a concrete run specification (DESIGN.md §5).
//!
//! Presets come in two scales: the CPU-friendly default (small synthetic
//! datasets, width-scaled models, fewer rounds) and `paper_scale` (the
//! published dimensions — expensive, intended for larger machines).

pub mod net;

use crate::coordinator::bicompfl::Variant;
use crate::mrc::block::AllocationStrategy;

/// Which block allocation to use for a BiCompFL method entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Alloc {
    Fixed,
    Adaptive,
    AdaptiveAvg,
}

impl Alloc {
    pub fn build(&self, n_is: usize, block_size: usize, b_max: usize) -> AllocationStrategy {
        match self {
            Alloc::Fixed => AllocationStrategy::fixed(block_size),
            Alloc::Adaptive => AllocationStrategy::adaptive(n_is, b_max),
            Alloc::AdaptiveAvg => AllocationStrategy::adaptive_avg(n_is, b_max),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Alloc::Fixed => "Fixed",
            Alloc::Adaptive => "Adaptive",
            Alloc::AdaptiveAvg => "Adaptive-Avg",
        }
    }
}

/// One BiCompFL method entry in a table (variant × allocation).
#[derive(Clone, Copy, Debug)]
pub struct BiCompFlMethod {
    pub variant: Variant,
    pub alloc: Alloc,
}

impl BiCompFlMethod {
    pub fn label(&self) -> String {
        format!("{}-{}", self.variant.label(), self.alloc.label())
    }
}

/// The method set of the paper's tables (Appendix I).
pub fn table_methods() -> Vec<BiCompFlMethod> {
    use Variant::*;
    vec![
        BiCompFlMethod { variant: Gr, alloc: Alloc::Adaptive },
        BiCompFlMethod { variant: Gr, alloc: Alloc::AdaptiveAvg },
        BiCompFlMethod { variant: Gr, alloc: Alloc::Fixed },
        BiCompFlMethod { variant: GrReconst, alloc: Alloc::Fixed },
        BiCompFlMethod { variant: Pr, alloc: Alloc::Fixed },
        BiCompFlMethod { variant: PrSplitDl, alloc: Alloc::Fixed },
    ]
}

/// A full experiment specification (one table or figure).
#[derive(Clone, Debug)]
pub struct ExpConfig {
    pub preset: String,
    pub dataset: String, // synth spec name
    pub arch: String,
    pub iid: bool,
    pub dirichlet_alpha: f64,
    pub n_clients: usize,
    pub rounds: usize,
    pub eval_every: usize,
    pub local_iters: usize,
    pub mask_lr: f32,
    pub server_lr: f32, // baselines
    pub cfl_server_lr: f32,
    pub n_is: usize,
    pub n_ul: usize,
    pub n_dl: usize, // 0 = auto
    pub block_size: usize,
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        Self {
            preset: "custom".into(),
            dataset: "mnist-like".into(),
            arch: "lenet5".into(),
            iid: true,
            dirichlet_alpha: 0.1,
            n_clients: 10,
            rounds: 30,
            eval_every: 5,
            local_iters: 3,
            mask_lr: 5.0,
            server_lr: 0.1,
            cfl_server_lr: 0.005,
            n_is: 256,
            n_ul: 1,
            n_dl: 0,
            block_size: 128,
            seed: 1,
        }
    }
}

/// Table/figure presets. `(dataset, arch, iid)` per Appendix I; the paper
/// trains 200 rounds (400 for CIFAR) — the default scale trims rounds for
/// CPU, `--rounds` overrides.
pub fn preset(name: &str) -> Option<ExpConfig> {
    let mut c = ExpConfig {
        preset: name.to_string(),
        ..Default::default()
    };
    match name {
        // Tables 5/6 + Fig 3/4.
        "mnist-lenet-iid" => {
            c.dataset = "mnist-like".into();
            c.arch = "lenet5".into();
        }
        "mnist-lenet-noniid" => {
            c.dataset = "mnist-like".into();
            c.arch = "lenet5".into();
            c.iid = false;
        }
        // Tables 7/8 + Fig 2(a,b), 5/6.
        "mnist-cnn4-iid" => {
            c.dataset = "mnist-like".into();
            c.arch = "cnn4".into();
        }
        "mnist-cnn4-noniid" => {
            c.dataset = "mnist-like".into();
            c.arch = "cnn4".into();
            c.iid = false;
        }
        // Tables 9/10 + Fig 1, 7/8.
        "fashion-cnn4-iid" => {
            c.dataset = "fashion-like".into();
            c.arch = "cnn4".into();
        }
        "fashion-cnn4-noniid" => {
            c.dataset = "fashion-like".into();
            c.arch = "cnn4".into();
            c.iid = false;
        }
        // Tables 11/12 + Fig 2(c), 9/10.
        "cifar-cnn6-iid" => {
            c.dataset = "cifar-like".into();
            c.arch = "cnn6".into();
            c.rounds = 40;
        }
        "cifar-cnn6-noniid" => {
            c.dataset = "cifar-like".into();
            c.arch = "cnn6".into();
            c.iid = false;
            c.rounds = 40;
        }
        // Fast smoke preset for CI / quickstart.
        "quick" => {
            c.arch = "mlp".into();
            c.rounds = 10;
            c.eval_every = 2;
        }
        _ => return None,
    }
    Some(c)
}

pub const PRESET_NAMES: &[&str] = &[
    "mnist-lenet-iid",
    "mnist-lenet-noniid",
    "mnist-cnn4-iid",
    "mnist-cnn4-noniid",
    "fashion-cnn4-iid",
    "fashion-cnn4-noniid",
    "cifar-cnn6-iid",
    "cifar-cnn6-noniid",
    "quick",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_resolve() {
        for name in PRESET_NAMES {
            let c = preset(name).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(&c.preset, name);
            assert!(crate::data::SynthSpec::by_name(&c.dataset).is_some(), "{name}");
        }
        assert!(preset("nope").is_none());
    }

    #[test]
    fn table_method_labels_unique() {
        let ms = table_methods();
        let mut labels: Vec<String> = ms.iter().map(|m| m.label()).collect();
        let before = labels.len();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), before);
    }

    #[test]
    fn alloc_builders_match_strategy() {
        assert_eq!(Alloc::Fixed.build(256, 128, 4096).name(), "Fixed");
        assert_eq!(Alloc::Adaptive.build(256, 128, 4096).name(), "Adaptive");
        assert_eq!(
            Alloc::AdaptiveAvg.build(256, 128, 4096).name(),
            "Adaptive-Avg"
        );
    }

    #[test]
    fn noniid_presets_flag_dirichlet() {
        assert!(!preset("mnist-cnn4-noniid").unwrap().iid);
        assert!(preset("mnist-cnn4-iid").unwrap().iid);
        assert_eq!(preset("mnist-cnn4-noniid").unwrap().dirichlet_alpha, 0.1);
    }
}
