//! `bicompfl` — the launcher.
//!
//! Subcommands:
//!   train     Run one BiCompFL training job (variant/allocation/dataset).
//!   exp       Regenerate a paper table/figure or an ablation sweep.
//!   presets   List experiment presets (one per paper table).
//!   info      Show the artifact manifest summary.
//!   federator Serve one multi-process BiCompFL-GR run over a Unix socket.
//!   client    Join a federator's run as one client process.
//!   mrc-smoke Stream one MRC encode/decode at large d in O(block) memory.
//!
//! Examples:
//!   bicompfl train --arch mlp --variant gr --rounds 20
//!   bicompfl exp table --preset mnist-lenet-iid
//!   bicompfl exp ablate-nis --fast
//!   bicompfl exp all-tables --fast
//!   bicompfl federator --sock /tmp/bicompfl.sock --clients 2 --rounds 3 &
//!   bicompfl client --sock /tmp/bicompfl.sock --id 0 &
//!   bicompfl client --sock /tmp/bicompfl.sock --id 1
//!   bicompfl federator --listen 127.0.0.1:7070 --clients 64 --rounds 3 &
//!   bicompfl client --connect 127.0.0.1:7070 --id 0
//!   bicompfl federator --topology net.toml & bicompfl client --topology net.toml --id 0

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use bicompfl::config::net::NetConfig;
use bicompfl::config::{preset, ExpConfig, PRESET_NAMES};
use bicompfl::coordinator::bicompfl::Variant;
use bicompfl::coordinator::distributed;
use bicompfl::exp::ablations;
use bicompfl::exp::tables::{run_table, MethodFilter};
use bicompfl::info;
use bicompfl::metrics::render_table;
use bicompfl::prss::SeedMode;
use bicompfl::util::cli::Cli;
use bicompfl::util::logging;

fn main() {
    logging::init();
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cli() -> Cli {
    Cli::new(
        "bicompfl — stochastic federated learning with bi-directional compression\n\n\
         Usage: bicompfl <train|exp|presets|info|federator|client|mrc-smoke> [flags]\n\
         exp subcommands: table, all-tables, ablate-clients, ablate-ndl,\n\
         ablate-blocksize, ablate-nis, ablate-prior\n\
         federator/client: a real multi-process BiCompFL-GR round loop over a\n\
         Unix-domain socket (--sock) or TCP (--listen/--connect/--topology);\n\
         the federator pushes the run config to every client during the\n\
         handshake, so clients only need an address and --id",
    )
    .flag("sock", "/tmp/bicompfl.sock", "federator/client: Unix socket path")
    .flag("listen", "", "federator: TCP listen address host:port (event-driven loop)")
    .flag("connect", "", "client: federator TCP address host:port")
    .flag(
        "topology",
        "",
        "net.toml with the federator listen address, client ids/addresses, \
         and cohort size (see config::net docs); explicit address flags win",
    )
    .flag("id", "0", "client: this client's id in 0..clients")
    .flag(
        "faults",
        "",
        "fault-injection spec, e.g. 'deadline_ms=200;1:delay_us=50000' \
         (docs/ARCHITECTURE.md, Fault model); overrides BICOMPFL_FAULTS",
    )
    .flag("d", "0", "federator: synthetic model dimension (0 = default 256); \
         mrc-smoke: streamed dimension (0 = default 10^7)")
    .flag(
        "chunk",
        "0",
        "federator: relay index payloads as CHUNK frames of this many \
         block-columns (0 = whole frames); bit-neutral on the meters",
    )
    .flag("preset", "quick", "experiment preset (see `bicompfl presets`)")
    .flag("arch", "", "model architecture (mlp|lenet5|cnn4|cnn6); overrides preset")
    .flag("dataset", "", "dataset (mnist-like|fashion-like|cifar-like); overrides preset")
    .flag("variant", "gr", "bicompfl variant (gr|gr-reconst|pr|pr-splitdl)")
    .flag("alloc", "fixed", "block allocation (fixed|adaptive|adaptive-avg)")
    .flag("rounds", "0", "global rounds (0 = preset default)")
    .flag("clients", "0", "number of clients (0 = preset default)")
    .flag("nis", "0", "importance samples per block (0 = preset default)")
    .flag("nul", "0", "uplink samples n_UL (0 = preset default)")
    .flag("ndl", "0", "downlink samples n_DL (0 = auto n*n_UL)")
    .flag("block-size", "0", "fixed block size (0 = preset default)")
    .flag("local-iters", "0", "local iterations per round (0 = preset default)")
    .flag("mask-lr", "0", "mask-training score learning rate (0 = preset default)")
    .flag(
        "threads",
        "0",
        "mrc-smoke: shard the block pipeline this wide across the worker \
         pool (0 = serial reference); bit-identical at every width",
    )
    .flag("seed", "1", "master seed")
    .flag(
        "seed-mode",
        "",
        "federator: seed establishment (ambient|negotiated); \
         overrides BICOMPFL_SEED_MODE",
    )
    .flag("out", "results", "output directory")
    .switch("fast", "use the synthetic oracle instead of PJRT artifacts")
    .switch("noniid", "force Dirichlet(0.1) data allocation")
    .switch("no-baselines", "exp table: skip non-stochastic baselines")
    .switch("no-cfl", "exp table: skip BiCompFL-GR-CFL")
}

/// The network configuration governing a federator/client process, resolved
/// in one place ([`NetConfig::from_env_and_args`]): the `--faults` and
/// `--topology` flags beat their environment variables (both sides read the
/// same environment, so launching a process group under one env var keeps
/// them in agreement). A `None` fault spec — including an explicit all-zero
/// one — selects the strict protocol.
fn net_config(c: &Cli) -> Result<NetConfig> {
    let faults = Some(c.get("faults")).filter(|s| !s.is_empty());
    let topology = Some(c.get("topology"))
        .filter(|s| !s.is_empty())
        .map(PathBuf::from);
    NetConfig::from_env_and_args(None, faults.as_deref(), topology.as_deref())
        .map_err(|e| anyhow!(e))
}

/// Where this federator listens / this client dials: an explicit flag
/// (`--listen` / `--connect`) wins, then the topology file, then the Unix
/// socket path.
fn net_addr(c: &Cli, flag: &str, topo_addr: Option<&str>) -> distributed::NetAddr {
    let explicit = c.get(flag);
    if !explicit.is_empty() {
        distributed::NetAddr::Tcp(explicit)
    } else if let Some(addr) = topo_addr {
        distributed::NetAddr::Tcp(addr.to_string())
    } else {
        distributed::NetAddr::Unix(PathBuf::from(c.get("sock")))
    }
}

/// The seed-establishment mode a federator serves: the `--seed-mode` flag
/// beats `BICOMPFL_SEED_MODE`, unset means ambient. Clients adopt whatever
/// mode the handshake ACK names, so only the federator consults this.
fn seed_mode_flag(c: &Cli) -> Result<SeedMode> {
    let v = c.get("seed-mode");
    if v.is_empty() {
        return SeedMode::from_env().map_err(|e| anyhow!(e));
    }
    SeedMode::parse(&v)
        .ok_or_else(|| anyhow!("unknown seed mode {v:?}; expected one of {:?}", SeedMode::NAMES))
}

fn build_cfg(c: &Cli) -> Result<ExpConfig> {
    let mut cfg = preset(&c.get("preset"))
        .ok_or_else(|| anyhow!("unknown preset {:?}; see `bicompfl presets`", c.get("preset")))?;
    let ov = |v: usize, cur: usize| if v == 0 { cur } else { v };
    cfg.rounds = ov(c.get_usize("rounds"), cfg.rounds);
    cfg.n_clients = ov(c.get_usize("clients"), cfg.n_clients);
    cfg.n_is = ov(c.get_usize("nis"), cfg.n_is);
    cfg.n_ul = ov(c.get_usize("nul"), cfg.n_ul);
    if c.get_usize("ndl") > 0 {
        cfg.n_dl = c.get_usize("ndl");
    }
    cfg.block_size = ov(c.get_usize("block-size"), cfg.block_size);
    cfg.local_iters = ov(c.get_usize("local-iters"), cfg.local_iters);
    if c.get_f32("mask-lr") > 0.0 {
        cfg.mask_lr = c.get_f32("mask-lr");
    }
    if !c.get("arch").is_empty() {
        cfg.arch = c.get("arch");
    }
    if !c.get("dataset").is_empty() {
        cfg.dataset = c.get("dataset");
    }
    if c.get_bool("noniid") {
        cfg.iid = false;
    }
    cfg.seed = c.get_u64("seed");
    Ok(cfg)
}

fn real_main() -> Result<()> {
    let c = cli().parse().map_err(|e| anyhow!(e))?;
    let cmd = c.positionals.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "presets" => {
            println!("available presets (one per paper table; DESIGN.md §5):");
            for p in PRESET_NAMES {
                println!("  {p}");
            }
        }
        "info" => {
            let m =
                bicompfl::runtime::Manifest::load(&bicompfl::runtime::manifest::default_dir())?;
            m.check()?;
            println!(
                "artifacts: {} modules, train_batch={}, eval_batch={}",
                m.artifacts.len(),
                m.train_batch,
                m.eval_batch
            );
            for a in &m.archs {
                println!(
                    "  arch {:<8} d={:<8} in={:?} width={}",
                    a.name, a.d, a.in_shape, a.width
                );
            }
        }
        "federator" => {
            // One multi-process BiCompFL-GR run: the run spec assembled here
            // travels to every client inside the handshake ACK, so the
            // processes cannot drift apart on a flag.
            let net = net_config(&c)?;
            let topo = net.topology.as_ref();
            let defaults = distributed::RunSpec::default();
            let nz = |v: usize, d: u32| if v == 0 { d } else { v as u32 };
            let n_default = topo.map(|t| t.n() as u32).unwrap_or(defaults.n);
            let spec = distributed::RunSpec {
                d: nz(c.get_usize("d"), defaults.d),
                n: nz(c.get_usize("clients"), n_default),
                rounds: nz(c.get_usize("rounds"), defaults.rounds),
                n_is: nz(c.get_usize("nis"), defaults.n_is),
                block_size: nz(c.get_usize("block-size"), defaults.block_size),
                n_ul: nz(c.get_usize("nul"), defaults.n_ul),
                local_iters: nz(c.get_usize("local-iters"), defaults.local_iters),
                seed: c.get_u64("seed"),
                chunk_blocks: c.get_usize("chunk") as u32,
                ..defaults
            };
            let at = net_addr(&c, "listen", topo.map(|t| t.listen.as_str()));
            info!(
                "federator: serving {} rounds for {} clients on {at:?}",
                spec.rounds, spec.n
            );
            let opts = distributed::RunOpts {
                spec,
                faults: net
                    .faults
                    .clone()
                    .unwrap_or_else(bicompfl::transport::FaultSpec::none),
                deadline: None,
                cohort: topo.and_then(|t| t.cohort),
                seed_mode: seed_mode_flag(&c)?,
            };
            if !opts.is_strict() {
                info!(
                    "federator: tolerant cohort protocol (faults {:?}, cohort {:?})",
                    opts.faults, opts.cohort
                );
            }
            let run = distributed::federate(&at, &opts)?;
            for r in &run.records {
                println!(
                    "round {:>4}: loss {:.4} acc {:.4} ul {} dl {} dl_bc {}",
                    r.round, r.loss, r.acc, r.ul_bits, r.dl_bits, r.dl_bc_bits
                );
            }
            println!(
                "wire: recv {} bits in {} frames, sent {} bits in {} frames",
                run.wire_recv.bits, run.wire_recv.frames, run.wire_sent.bits, run.wire_sent.frames
            );
            // Both federator loops hard-assert meter == records (the
            // tolerant one splitting out orphaned bits) before returning.
            println!("transport check: meter == records ok");
            if !opts.is_strict() {
                for f in &run.faults.clients {
                    println!(
                        "faults: client {}: delivered {} straggled {} dropped {} retries {}",
                        f.client, f.delivered, f.straggled, f.dropped, f.retries
                    );
                }
            }
        }
        "client" => {
            let net = net_config(&c)?;
            let id = c.get_u64("id");
            let topo_addr = match net.topology.as_ref() {
                Some(t) => Some(
                    t.addr_of(id)
                        .ok_or_else(|| anyhow!("client id {id} is not in the topology"))?,
                ),
                None => None,
            };
            let at = net_addr(&c, "connect", topo_addr);
            let opts = distributed::RunOpts {
                faults: net
                    .faults
                    .clone()
                    .unwrap_or_else(bicompfl::transport::FaultSpec::none),
                ..Default::default()
            };
            distributed::participate(&at, id, &opts)?;
            println!("client {id}: run complete, federator said bye");
        }
        "mrc-smoke" => {
            // Streaming MRC memory smoke: encode and decode a d-dimensional
            // vector without ever materializing it — per-entry parameters
            // are a pure function of the entry index, so live memory is
            // O(block), not O(d). The CI `large-d-memory` job runs this at
            // d = 10⁷ under `/usr/bin/time -v` and fails the build if peak
            // RSS crosses the declared ceiling.
            let d = match c.get_usize("d") {
                0 => 10_000_000,
                v => v,
            };
            let bs = match c.get_usize("block-size") {
                0 => 256,
                v => v,
            };
            let n_is = match c.get_usize("nis") {
                0 => 64,
                v => v,
            };
            let n_ul = match c.get_usize("nul") {
                0 => 1,
                v => v,
            };
            mrc_smoke(d, bs, n_is, n_ul, c.get_usize("threads"), c.get_u64("seed"))?;
        }
        "train" => {
            let cfg = build_cfg(&c)?;
            let variant = match c.get("variant").as_str() {
                "gr" => Variant::Gr,
                "gr-reconst" => Variant::GrReconst,
                "pr" => Variant::Pr,
                "pr-splitdl" => Variant::PrSplitDl,
                v => return Err(anyhow!("unknown variant {v}")),
            };
            let alloc = match c.get("alloc").as_str() {
                "fixed" => bicompfl::config::Alloc::Fixed,
                "adaptive" => bicompfl::config::Alloc::Adaptive,
                "adaptive-avg" => bicompfl::config::Alloc::AdaptiveAvg,
                v => return Err(anyhow!("unknown alloc {v}")),
            };
            let method = bicompfl::config::BiCompFlMethod { variant, alloc };
            info!("train: {} on {}/{}", method.label(), cfg.dataset, cfg.arch);
            let (d, recs) = if c.get_bool("fast") {
                let mut oracle = bicompfl::exp::build_synthetic_oracle(&cfg);
                let d = bicompfl::coordinator::MaskOracle::dim(&oracle);
                (d, bicompfl::exp::run_bicompfl(&cfg, &method, &mut oracle))
            } else {
                let mut oracle = bicompfl::exp::build_runtime_oracle(&cfg)?;
                let d = oracle.arch.d;
                (d, bicompfl::exp::run_bicompfl(&cfg, &method, &mut oracle))
            };
            for r in &recs {
                println!(
                    "round {:>4}: loss {:.4} acc {:.4} ul {} dl {}",
                    r.round, r.loss, r.acc, r.ul_bits, r.dl_bits
                );
            }
            let rows = vec![bicompfl::metrics::TableRow::from_records(
                &method.label(),
                &recs,
                d,
                cfg.n_clients,
            )];
            println!("{}", render_table("train", &rows));
        }
        "exp" => {
            let sub = c.positionals.get(1).map(|s| s.as_str()).unwrap_or("table");
            let cfg = build_cfg(&c)?;
            let fast = c.get_bool("fast");
            let out = PathBuf::from(c.get("out"));
            match sub {
                "table" => {
                    let filter = MethodFilter {
                        baselines: !c.get_bool("no-baselines"),
                        bicompfl: true,
                        cfl: !c.get_bool("no-cfl"),
                    };
                    run_table(&cfg, filter, fast, &out)?;
                }
                "all-tables" => {
                    for p in PRESET_NAMES.iter().filter(|p| **p != "quick") {
                        let mut pc = preset(p).unwrap();
                        if c.get_usize("rounds") > 0 {
                            pc.rounds = c.get_usize("rounds");
                        }
                        pc.seed = cfg.seed;
                        run_table(&pc, MethodFilter::default(), fast, &out)?;
                    }
                }
                "ablate-clients" => {
                    ablations::ablate_clients(&cfg, fast, &out)?;
                }
                "ablate-ndl" => {
                    ablations::ablate_ndl(&cfg, fast, &out)?;
                }
                "ablate-blocksize" => {
                    ablations::ablate_blocksize(&cfg, fast, &out)?;
                }
                "ablate-nis" => {
                    ablations::ablate_nis(&cfg, fast, &out)?;
                }
                "ablate-prior" => {
                    ablations::ablate_prior(&cfg, fast, &out)?;
                }
                other => return Err(anyhow!("unknown exp subcommand {other}")),
            }
        }
        _ => {
            eprintln!("{}", cli().usage());
        }
    }
    Ok(())
}

/// One streamed MRC encode + decode at dimension `d`, never holding a
/// d-length vector: posterior/prior entries are regenerated per block from
/// counter-based Philox draws, index columns drain into the kept wire
/// payload (4 bytes per block-sample — the only state that grows with
/// d/block), and the decoder folds every regenerated mean into a checksum.
/// With `threads > 1` both legs run the parallel block pipeline `threads`
/// shards wide (peak memory O(block × threads), results bit-identical to
/// the serial reference — the checksum fold stays in ascending block
/// order). Asserts wire == analytic bits and prints one summary line the CI
/// memory job greps.
fn mrc_smoke(
    d: usize,
    bs: usize,
    n_is: usize,
    n_ul: usize,
    threads: usize,
    seed: u64,
) -> Result<()> {
    use bicompfl::mrc::{decode_stream_parallel, encode_stream_parallel, BlockPlan};
    use bicompfl::util::rng::Philox;

    let shards = threads.max(1);
    let plan = BlockPlan::fixed(d, bs);
    let n_blocks = plan.n_blocks();
    let q_src = Philox::keyed(seed, 1);
    let p_src = Philox::keyed(seed, 2);
    let param = |src: &Philox, e: usize| 0.05 + 0.9 * src.uniform_at(e as u64);
    let stream_for = |b: u64| Philox::keyed(seed ^ 0xB10C_57EA, b);

    let mut columns: Vec<u32> = Vec::with_capacity(n_blocks * n_ul);
    let bits = encode_stream_parallel(
        n_is,
        n_ul,
        seed ^ 0x5E1,
        &plan,
        shards,
        stream_for,
        |_b, r, qb, pb| {
            qb.extend(r.clone().map(|e| param(&q_src, e)));
            pb.extend(r.map(|e| param(&p_src, e)));
        },
        |_b, column| columns.extend_from_slice(column),
    );
    let index_bits = u64::from(u32::BITS - (n_is as u32 - 1).leading_zeros());
    let analytic = n_blocks as u64 * n_ul as u64 * index_bits;
    anyhow::ensure!(
        bits == analytic,
        "wire bits {bits} != analytic {analytic} (blocks {n_blocks} x n_ul {n_ul} x {index_bits})"
    );

    let block_sums = decode_stream_parallel(
        n_is,
        n_ul,
        &plan,
        shards,
        &columns,
        stream_for,
        |_b, r, pb| pb.extend(r.map(|e| param(&p_src, e))),
        |_b, out| out.iter().map(|&v| f64::from(v)).sum::<f64>(),
    );
    // Ascending-block fold — the serial checksum's exact f64 sequence.
    let checksum: f64 = block_sums.iter().sum();
    println!(
        "mrc-smoke ok: d={d} blocks={n_blocks} n_is={n_is} n_ul={n_ul} threads={shards} \
         bits={bits} mean={:.6}",
        checksum / d as f64
    );
    Ok(())
}
