//! The QSGD-style stochastic quantizer Q_s (Alistarh et al. 2017), exactly
//! as defined in the paper's §5: for s quantization intervals and entry g_e,
//! with integer τ_e s.t. τ_e/s ≤ |g_e|/‖g‖ ≤ (τ_e+1)/s,
//!
//!   Q_s(g_e) = ‖g‖ sign(g_e) (τ_e+1)/s  w.p.  |g_e|/‖g‖·s − τ_e,
//!              ‖g‖ sign(g_e)  τ_e   /s  otherwise.
//!
//! Q_s is unbiased with variance ≤ min(d/s², √d/s)·‖g‖². Its Bernoulli
//! success probabilities are what BiCompFL composes with MRC (Lemma 1):
//! [`Qs::posterior`] exposes them, and [`Qs::reconstruct`] maps sampled bits
//! back to quantized values.

use super::Compressor;
use crate::tensor::norm2;
use crate::util::rng::Xoshiro256;

#[derive(Clone, Copy, Debug)]
pub struct Qs {
    /// Number of quantization intervals (s ≥ 1; Lemma 1 wants s ≥ √(2d)).
    pub s: usize,
}

/// Decomposition of Q_s(g): everything except the Bernoulli outcomes.
pub struct QsPosterior {
    pub norm: f32,
    pub signs: Vec<f32>,  // ±1
    pub tau: Vec<u32>,    // lower level index per entry
    pub q: Vec<f32>,      // Bernoulli success probability per entry
}

impl Qs {
    /// Bernoulli decomposition: q_e = |g_e|/‖g‖·s − τ_e.
    pub fn posterior(&self, g: &[f32]) -> QsPosterior {
        let norm = norm2(g) as f32;
        let s = self.s as f32;
        let mut signs = Vec::with_capacity(g.len());
        let mut tau = Vec::with_capacity(g.len());
        let mut q = Vec::with_capacity(g.len());
        for &x in g {
            signs.push(if x >= 0.0 { 1.0 } else { -1.0 });
            if norm == 0.0 {
                tau.push(0);
                q.push(0.0);
                continue;
            }
            let r = (x.abs() / norm * s).min(s - 1e-6);
            let t = r.floor();
            tau.push(t as u32);
            q.push(r - t);
        }
        QsPosterior {
            norm,
            signs,
            tau,
            q,
        }
    }

    /// Map Bernoulli outcomes b ∈ {0,1}^d back to quantized values.
    pub fn reconstruct(&self, post: &QsPosterior, bits: &[f32], out: &mut [f32]) {
        let s = self.s as f32;
        for e in 0..bits.len() {
            let level = post.tau[e] as f32 + bits[e];
            out[e] = post.norm * post.signs[e] * level / s;
        }
    }

    /// Plain-binary width of one τ value: ceil(log2 s) bits. The single
    /// source of truth for τ coding — the transport layer's Q_s side-info
    /// frames use the same width, so wire and compressor accounting cannot
    /// drift apart.
    pub fn tau_bits(&self) -> u8 {
        (usize::BITS - self.s.saturating_sub(1).leading_zeros()) as u8
    }

    /// Bits for the side information (‖g‖, signs, τ) assuming plain binary
    /// coding of τ (the paper notes Elias coding applies; binary is an upper
    /// bound and keeps accounting deterministic).
    pub fn side_bits(&self, d: usize) -> u64 {
        32 + d as u64 * (1 + self.tau_bits() as u64)
    }
}

impl Compressor for Qs {
    fn name(&self) -> &'static str {
        "qsgd"
    }

    fn compress(&mut self, g: &[f32], rng: &mut Xoshiro256) -> (Vec<f32>, u64) {
        let post = self.posterior(g);
        let bits: Vec<f32> = post
            .q
            .iter()
            .map(|&qe| if rng.next_f32() < qe { 1.0 } else { 0.0 })
            .collect();
        let mut out = vec![0.0f32; g.len()];
        self.reconstruct(&post, &bits, &mut out);
        // Direct transmission: side info + 1 Bernoulli outcome bit per entry.
        let cost = self.side_bits(g.len()) + g.len() as u64;
        (out, cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run_prop, vec_f32};

    #[test]
    fn posterior_in_unit_interval_and_levels_valid() {
        run_prop("qs-posterior", 100, |rng, _| {
            let d = 1 + rng.next_below(64);
            let g = vec_f32(rng, d, -3.0, 3.0);
            let qs = Qs {
                s: 1 + rng.next_below(32),
            };
            let post = qs.posterior(&g);
            for e in 0..d {
                assert!((0.0..=1.0).contains(&post.q[e]), "q={}", post.q[e]);
                assert!((post.tau[e] as usize) < qs.s);
            }
        });
    }

    #[test]
    fn unbiasedness() {
        // E[Q_s(x)] == x, verified by averaging many stochastic draws.
        let g = vec![0.7f32, -0.2, 0.05, 1.3, -0.9];
        let mut qs = Qs { s: 4 };
        let mut acc = vec![0.0f64; g.len()];
        let mut rng = Xoshiro256::new(42);
        let reps = 20_000;
        for _ in 0..reps {
            let (out, _) = qs.compress(&g, &mut rng);
            for (a, o) in acc.iter_mut().zip(&out) {
                *a += *o as f64;
            }
        }
        for (a, &x) in acc.iter().zip(&g) {
            let mean = a / reps as f64;
            assert!(
                (mean - x as f64).abs() < 0.01,
                "E[Qs] = {mean}, x = {x}"
            );
        }
    }

    #[test]
    fn variance_bound_alistarh() {
        // E||Q_s(x) - x||^2 <= min(d/s^2, sqrt(d)/s) ||x||^2.
        let mut rng = Xoshiro256::new(7);
        for &s in &[2usize, 8, 32] {
            let d = 16;
            let g: Vec<f32> = (0..d).map(|i| ((i as f32) - 8.0) * 0.3).collect();
            let norm_sq: f64 = g.iter().map(|x| (*x as f64).powi(2)).sum();
            let mut qs = Qs { s };
            let reps = 5000;
            let mut err = 0.0f64;
            for _ in 0..reps {
                let (out, _) = qs.compress(&g, &mut rng);
                err += out
                    .iter()
                    .zip(&g)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>();
            }
            err /= reps as f64;
            let bound = (d as f64 / (s * s) as f64).min((d as f64).sqrt() / s as f64);
            assert!(
                err <= bound * norm_sq * 1.05,
                "s={s}: var {err} > bound {}",
                bound * norm_sq
            );
        }
    }

    #[test]
    fn reconstruct_is_exact_inverse_of_bits() {
        let g = vec![0.5f32, -1.5, 2.0];
        let qs = Qs { s: 8 };
        let post = qs.posterior(&g);
        let mut lo = vec![0.0f32; 3];
        let mut hi = vec![0.0f32; 3];
        qs.reconstruct(&post, &[0.0, 0.0, 0.0], &mut lo);
        qs.reconstruct(&post, &[1.0, 1.0, 1.0], &mut hi);
        for e in 0..3 {
            assert!(lo[e].abs() <= g[e].abs() + 1e-6);
            assert!(hi[e].abs() >= g[e].abs() - 1e-6);
            assert_eq!(lo[e] >= 0.0, g[e] >= 0.0);
        }
    }

    #[test]
    fn zero_vector_safe() {
        let g = vec![0.0f32; 4];
        let (out, _) = Qs { s: 4 }.compress(&g, &mut Xoshiro256::new(0));
        assert_eq!(out, g);
    }

    use crate::util::rng::Xoshiro256;
}
