//! Gradient compressors used by the non-stochastic baselines (§4, §6) and by
//! the stochastic-quantization path of BiCompFL-GR-CFL (§5).
//!
//! Every compressor reports its *exact* bit cost alongside the compressed
//! vector; the experiment tables are bit-accounting driven, so costs are
//! first-class outputs, not estimates.

pub mod sign;
pub mod topk;
pub mod qsgd;
pub mod error_feedback;

pub use error_feedback::Memory;
pub use qsgd::Qs;
pub use sign::{sign_compress, stochastic_sign_posterior, SignCompressor};
pub use topk::{RandK, TopK};

use crate::util::rng::Xoshiro256;

/// A lossy gradient compressor: `compress` maps g to an approximation and
/// the exact number of bits a transmission of that approximation costs.
pub trait Compressor {
    fn name(&self) -> &'static str;
    fn compress(&mut self, g: &[f32], rng: &mut Xoshiro256) -> (Vec<f32>, u64);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trait_objects_work() {
        let mut cs: Vec<Box<dyn Compressor>> = vec![
            Box::new(SignCompressor),
            Box::new(TopK { k: 2 }),
            Box::new(RandK { k: 2 }),
            Box::new(Qs { s: 4 }),
        ];
        let g = vec![0.5f32, -1.0, 2.0, -0.25];
        let mut rng = Xoshiro256::new(0);
        for c in cs.iter_mut() {
            let (out, bits) = c.compress(&g, &mut rng);
            assert_eq!(out.len(), g.len(), "{}", c.name());
            assert!(bits > 0);
        }
    }
}
