//! Error-feedback memory (Stich et al. 2018; Karimireddy et al. 2019).
//!
//! The residual of a biased compressor is accumulated and re-injected into
//! the next round's input: p_t = g_t + e_t; e_{t+1} = p_t − C(p_t). Used by
//! MemSGD, DoubleSqueeze (both ends), CSER (with reset), LIEC, Neolithic.

use crate::tensor;

#[derive(Clone, Debug)]
pub struct Memory {
    pub e: Vec<f32>,
}

impl Memory {
    pub fn new(d: usize) -> Self {
        Self { e: vec![0.0; d] }
    }

    /// p = g + e (returns the compensated vector).
    pub fn compensate(&self, g: &[f32]) -> Vec<f32> {
        let mut p = g.to_vec();
        tensor::add_assign(&mut p, &self.e);
        p
    }

    /// e ← p − c  (store the new residual after compressing p to c).
    pub fn update(&mut self, p: &[f32], c: &[f32]) {
        debug_assert_eq!(p.len(), c.len());
        for ((e, &pv), &cv) in self.e.iter_mut().zip(p).zip(c) {
            *e = pv - cv;
        }
    }

    /// CSER-style error reset.
    pub fn reset(&mut self) {
        self.e.iter_mut().for_each(|x| *x = 0.0);
    }

    pub fn norm(&self) -> f64 {
        tensor::norm2(&self.e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{sign_compress, Compressor, TopK};
    use crate::util::prop::{run_prop, vec_f32};
    use crate::util::rng::Xoshiro256;

    #[test]
    fn residual_identity() {
        let mut m = Memory::new(3);
        let g = vec![1.0f32, -2.0, 0.5];
        let p = m.compensate(&g);
        assert_eq!(p, g); // zero initial memory
        let (c, _) = sign_compress(&p);
        m.update(&p, &c);
        // p = c + e exactly.
        for i in 0..3 {
            assert!((c[i] + m.e[i] - p[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn ef_recovers_mean_signal_over_time() {
        // With a constant gradient and TopK-1 compression, error feedback
        // must transmit every coordinate eventually: the sum of compressed
        // outputs approaches t * g.
        let g = vec![1.0f32, 0.8, 0.6, 0.4];
        let mut m = Memory::new(4);
        let mut sum = vec![0.0f32; 4];
        let mut rng = Xoshiro256::new(0);
        let t = 200;
        for _ in 0..t {
            let p = m.compensate(&g);
            let (c, _) = TopK { k: 1 }.compress(&p, &mut rng);
            m.update(&p, &c);
            tensor::add_assign(&mut sum, &c);
        }
        for i in 0..4 {
            let avg = sum[i] / t as f32;
            assert!(
                (avg - g[i]).abs() < 0.05,
                "coordinate {i}: long-run mean {avg} vs {g:?}"
            );
        }
    }

    #[test]
    fn reset_clears() {
        let mut m = Memory::new(2);
        m.e = vec![1.0, 2.0];
        m.reset();
        assert_eq!(m.e, vec![0.0, 0.0]);
        assert_eq!(m.norm(), 0.0);
    }

    #[test]
    fn prop_memory_bounded_under_contractive_compressor() {
        // For a delta-contractive compressor, ||e_t|| stays bounded given
        // bounded inputs (classic EF stability).
        run_prop("ef-bounded", 10, |rng, _| {
            let d = 8;
            let mut m = Memory::new(d);
            let mut topk = TopK { k: 2 };
            for _ in 0..100 {
                let g = vec_f32(rng, d, -1.0, 1.0);
                let p = m.compensate(&g);
                let (c, _) = topk.compress(&p, rng);
                m.update(&p, &c);
            }
            assert!(m.norm() < 50.0, "memory exploded: {}", m.norm());
        });
    }
}
