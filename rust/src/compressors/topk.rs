//! Sparsification compressors: TopK (Wangni et al. 2018; used by M3's uplink
//! in our experiments, per §4) and RandK (M3's original choice, kept for the
//! ablation). Bit cost: k values at 32 bits + k indices at ceil(log2 d) bits.

use super::Compressor;
use crate::util::rng::Xoshiro256;

fn index_bits(d: usize) -> u64 {
    (usize::BITS - d.saturating_sub(1).leading_zeros()).max(1) as u64
}

/// Keep the k largest-magnitude entries.
pub struct TopK {
    pub k: usize,
}

impl TopK {
    /// The indices of the k largest-magnitude entries (partial-sort order —
    /// deterministic for a given input, not sorted). This is the message
    /// content a sparse transmission carries; `compress` and the transport
    /// layer's sparse frames share it so their payloads cannot drift apart.
    pub fn select(&self, g: &[f32]) -> Vec<u32> {
        let d = g.len();
        let k = self.k.min(d);
        if k == 0 || d == 0 {
            return Vec::new();
        }
        let mut idx: Vec<usize> = (0..d).collect();
        idx.select_nth_unstable_by(k.saturating_sub(1).min(d - 1), |&a, &b| {
            g[b].abs().partial_cmp(&g[a].abs()).unwrap()
        });
        idx[..k].iter().map(|&i| i as u32).collect()
    }
}

impl Compressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn compress(&mut self, g: &[f32], _rng: &mut Xoshiro256) -> (Vec<f32>, u64) {
        let d = g.len();
        let idx = self.select(g);
        let mut out = vec![0.0f32; d];
        for &i in &idx {
            out[i as usize] = g[i as usize];
        }
        (out, idx.len() as u64 * (32 + index_bits(d)))
    }
}

/// Keep k uniformly random entries, unscaled (biased variant; the unbiased
/// d/k-scaled variant is a flag since both appear in the literature).
pub struct RandK {
    pub k: usize,
}

impl Compressor for RandK {
    fn name(&self) -> &'static str {
        "randk"
    }

    fn compress(&mut self, g: &[f32], rng: &mut Xoshiro256) -> (Vec<f32>, u64) {
        let d = g.len();
        let k = self.k.min(d);
        let mut idx: Vec<usize> = (0..d).collect();
        rng.shuffle(&mut idx);
        let mut out = vec![0.0f32; d];
        for &i in &idx[..k] {
            out[i] = g[i];
        }
        (out, k as u64 * (32 + index_bits(d)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run_prop, vec_f32};

    #[test]
    fn topk_keeps_largest() {
        let g = vec![0.1f32, -5.0, 0.2, 3.0, -0.05];
        let (out, bits) = TopK { k: 2 }.compress(&g, &mut Xoshiro256::new(0));
        assert_eq!(out, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
        assert_eq!(bits, 2 * (32 + 3));
    }

    #[test]
    fn topk_is_contractive() {
        // ||TopK(g) - g||^2 = ||g||^2 - ||TopK(g)||^2 <= (1 - k/d) ||g||^2.
        run_prop("topk-contraction", 100, |rng, _| {
            let d = 2 + rng.next_below(200);
            let k = 1 + rng.next_below(d);
            let g = vec_f32(rng, d, -2.0, 2.0);
            let (out, _) = TopK { k }.compress(&g, rng);
            let err: f64 = out
                .iter()
                .zip(&g)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            let norm: f64 = g.iter().map(|x| (*x as f64).powi(2)).sum();
            assert!(err <= (1.0 - k as f64 / d as f64) * norm + 1e-6);
        });
    }

    #[test]
    fn randk_keeps_exactly_k() {
        run_prop("randk-support", 50, |rng, _| {
            let d = 1 + rng.next_below(100);
            let k = 1 + rng.next_below(d);
            let g = vec_f32(rng, d, 0.5, 1.0); // strictly nonzero
            let (out, _) = RandK { k }.compress(&g, rng);
            let nz = out.iter().filter(|&&x| x != 0.0).count();
            assert_eq!(nz, k);
            // Kept entries are unmodified.
            for (o, orig) in out.iter().zip(&g) {
                assert!(*o == 0.0 || o == orig);
            }
        });
    }

    #[test]
    fn k_larger_than_d_is_identity() {
        let g = vec![1.0f32, 2.0];
        let (out, _) = TopK { k: 10 }.compress(&g, &mut Xoshiro256::new(0));
        assert_eq!(out, g);
    }

    use crate::util::rng::Xoshiro256;
}
