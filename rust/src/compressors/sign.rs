//! Sign-based compression.
//!
//! * [`sign_compress`] / [`SignCompressor`] — classic 1-bit SGD (Seide et al.
//!   2014): transmit sign(g) plus one scale ‖g‖₁/d; cost d + 32 bits. This is
//!   the compressor the paper plugs into every non-stochastic baseline.
//! * [`stochastic_sign_posterior`] — the §4 stochastic SignSGD front-end of
//!   BiCompFL-GR-CFL: maps each gradient entry to a Bernoulli parameter
//!   q_e = 1 / (1 + exp(−g_e / K)); the *samples* take value +1 w.p. q_e and
//!   −1 otherwise, and are carried by MRC rather than transmitted directly.

use super::Compressor;
use crate::util::rng::Xoshiro256;

/// sign(g) scaled by the mean magnitude; (compressed, bits = d + 32).
pub fn sign_compress(g: &[f32]) -> (Vec<f32>, u64) {
    let d = g.len();
    let scale = (g.iter().map(|x| x.abs() as f64).sum::<f64>() / d.max(1) as f64) as f32;
    let out = g
        .iter()
        .map(|&x| if x >= 0.0 { scale } else { -scale })
        .collect();
    (out, d as u64 + 32)
}

pub struct SignCompressor;

impl Compressor for SignCompressor {
    fn name(&self) -> &'static str {
        "sign"
    }

    fn compress(&mut self, g: &[f32], _rng: &mut Xoshiro256) -> (Vec<f32>, u64) {
        sign_compress(g)
    }
}

/// Bernoulli posterior of stochastic SignSGD: q_e = sigmoid(g_e / K).
/// A sample b_e ∈ {0,1} decodes to the update (2 b_e − 1), i.e. ±1.
pub fn stochastic_sign_posterior(g: &[f32], k: f32, out: &mut [f32]) {
    debug_assert_eq!(g.len(), out.len());
    for (o, &x) in out.iter_mut().zip(g) {
        *o = crate::tensor::sigmoid(x / k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run_prop, vec_f32};

    #[test]
    fn sign_preserves_signs_and_scale() {
        let g = vec![3.0f32, -1.0, 0.5, -0.5];
        let (c, bits) = sign_compress(&g);
        assert_eq!(bits, 4 + 32);
        let scale = (3.0 + 1.0 + 0.5 + 0.5) / 4.0;
        assert_eq!(c, vec![scale, -scale, scale, -scale]);
    }

    #[test]
    fn sign_is_contractive_for_uniformish_vectors() {
        // ||C(g) - g||^2 <= ||g||^2 is not universal for sign, but holds for
        // well-spread vectors; check the classic identity on a random sweep
        // only as a sanity signal of scaling, not a hard contraction claim.
        run_prop("sign-bounded", 50, |rng, _| {
            let n = 1 + rng.next_below(100);
            let g = vec_f32(rng, n, -1.0, 1.0);
            let (c, _) = sign_compress(&g);
            let err: f64 = c
                .iter()
                .zip(&g)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            let norm: f64 = g.iter().map(|x| (*x as f64).powi(2)).sum();
            assert!(err <= 4.0 * norm + 1e-9);
        });
    }

    #[test]
    fn stochastic_posterior_matches_paper_formula() {
        let g = vec![0.0f32, 1.0, -1.0, 100.0];
        let mut q = vec![0.0f32; 4];
        stochastic_sign_posterior(&g, 1.0, &mut q);
        assert!((q[0] - 0.5).abs() < 1e-6);
        assert!((q[1] - 1.0 / (1.0 + (-1.0f32).exp())).abs() < 1e-6);
        assert!((q[1] + q[2] - 1.0).abs() < 1e-6); // symmetry
        assert!(q[3] > 0.999);
        // Temperature: larger K flattens toward 0.5.
        let mut qk = vec![0.0f32; 4];
        stochastic_sign_posterior(&g, 10.0, &mut qk);
        assert!((qk[1] - 0.5).abs() < (q[1] - 0.5).abs());
    }
}
