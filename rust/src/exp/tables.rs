//! Table / figure driver: run every method of the paper's evaluation on one
//! setting and emit (a) the per-round CSV behind Figures 1 & 3–11, (b) the
//! summary table behind Tables 5–12 and the Fig. 2 scatter.
//!
//! Methods: 7 non-stochastic baselines (gradient path), 6 BiCompFL mask-
//! training entries (GR-{Adaptive,Adaptive-Avg,Fixed}, GR-Reconst-Fixed,
//! PR-Fixed, PR-Fixed-SplitDL), plus BiCompFL-GR-CFL (stochastic sign).

use std::path::Path;

use anyhow::Result;

use super::{build_runtime_oracle, build_synthetic_oracle, run_bicompfl};
use crate::coordinator::MaskOracle;
use crate::algorithms::runner::{run_algorithm, RoundRecord};
use crate::algorithms::{make_baseline, CflAlgorithm, QuadraticOracle, BASELINE_NAMES};
use crate::config::{table_methods, ExpConfig};
use crate::coordinator::cfl::{BiCompFlCfl, CflConfig, Quantizer};
use crate::metrics::{render_table, write_summary_json, CsvLog, TableRow};

/// Which method families to include.
#[derive(Clone, Copy, Debug)]
pub struct MethodFilter {
    pub baselines: bool,
    pub bicompfl: bool,
    pub cfl: bool,
}

impl Default for MethodFilter {
    fn default() -> Self {
        Self {
            baselines: true,
            bicompfl: true,
            cfl: true,
        }
    }
}

pub struct TableOutput {
    pub rows: Vec<TableRow>,
    pub d: usize,
}

/// Run the full method set for one experiment setting.
///
/// `fast` replaces the PJRT oracle with synthetic stand-ins (identical
/// coordinator code, closed-form Layer 2) — used by tests and smoke runs.
pub fn run_table(
    cfg: &ExpConfig,
    filter: MethodFilter,
    fast: bool,
    out_dir: &Path,
) -> Result<TableOutput> {
    let mut csv = CsvLog::create(&out_dir.join(format!("{}.csv", cfg.preset)))?;
    let mut rows: Vec<TableRow> = Vec::new();
    let n = cfg.n_clients;

    // Establish the model dimension once.
    let d = if fast {
        build_synthetic_oracle(cfg).dim()
    } else {
        build_runtime_oracle(cfg)?.arch.d
    };

    // -- non-stochastic baselines (gradient path) --------------------------
    if filter.baselines {
        for name in BASELINE_NAMES {
            let recs = if fast {
                let dd = d.min(4096);
                let mut oracle = QuadraticOracle::new(dd, n, cfg.seed);
                let mut alg = make_baseline(name, dd, n, 0.3).unwrap();
                run_algorithm(alg.as_mut(), &mut oracle, cfg.rounds, cfg.eval_every, cfg.seed)
            } else {
                let mut oracle = build_runtime_oracle(cfg)?;
                let mut alg = make_baseline(name, d, n, cfg.server_lr).unwrap();
                // Symmetry-breaking init: start from the oracle's
                // signed-constant weights (an all-zero CNN has zero grads).
                alg.set_params(&oracle.weights);
                run_algorithm(alg.as_mut(), &mut oracle, cfg.rounds, cfg.eval_every, cfg.seed)
            };
            let label = display_name(name);
            csv.log_all(&label, &recs)?;
            rows.push(TableRow::from_records(&label, &recs, d_for(fast, d), n));
            crate::info!("table {}: {} done", cfg.preset, label);
        }
    }

    // -- BiCompFL mask-training variants ------------------------------------
    if filter.bicompfl {
        for m in table_methods() {
            let recs = if fast {
                let mut oracle = build_synthetic_oracle(cfg);
                run_bicompfl(cfg, &m, &mut oracle)
            } else {
                let mut oracle = build_runtime_oracle(cfg)?;
                run_bicompfl(cfg, &m, &mut oracle)
            };
            let label = m.label();
            csv.log_all(&label, &recs)?;
            rows.push(TableRow::from_records(&label, &recs, d_for(fast, d), n));
            crate::info!("table {}: {} done", cfg.preset, label);
        }
    }

    // -- BiCompFL-GR-CFL (stochastic sign through MRC) ----------------------
    if filter.cfl {
        let recs = run_cfl(cfg, fast, d)?;
        csv.log_all("BiCompFL-GR-CFL", &recs)?;
        rows.push(TableRow::from_records(
            "BiCompFL-GR-CFL",
            &recs,
            d_for(fast, d),
            n,
        ));
        crate::info!("table {}: BiCompFL-GR-CFL done", cfg.preset);
    }

    write_summary_json(&out_dir.join(format!("{}.json", cfg.preset)), &cfg.preset, &rows)?;
    println!("{}", render_table(&cfg.preset, &rows));
    Ok(TableOutput { rows, d })
}

fn run_cfl(cfg: &ExpConfig, fast: bool, d: usize) -> Result<Vec<RoundRecord>> {
    let ccfg = CflConfig {
        quantizer: Quantizer::StochasticSign,
        n_is: cfg.n_is,
        n_ul: cfg.n_ul,
        block_size: cfg.block_size,
        server_lr: cfg.cfl_server_lr,
        seed: cfg.seed,
        ..Default::default()
    };
    Ok(if fast {
        let dd = d.min(4096);
        let mut oracle = QuadraticOracle::new(dd, cfg.n_clients, cfg.seed);
        let mut alg = BiCompFlCfl::new(dd, CflConfig { server_lr: 0.3, ..ccfg });
        run_algorithm(&mut alg, &mut oracle, cfg.rounds, cfg.eval_every, cfg.seed)
    } else {
        let mut oracle = build_runtime_oracle(cfg)?;
        let mut alg = BiCompFlCfl::new(d, ccfg);
        alg.set_params(&oracle.weights);
        run_algorithm(&mut alg, &mut oracle, cfg.rounds, cfg.eval_every, cfg.seed)
    })
}

/// The dimension used for bpp normalization: the synthetic substitutes cap
/// d at 4096 (both the quadratic and mask oracles), the real path uses the
/// arch's true d.
fn d_for(fast: bool, d: usize) -> usize {
    if fast {
        d.min(4096)
    } else {
        d
    }
}

fn display_name(name: &str) -> String {
    match name {
        "fedavg" => "FedAvg".into(),
        "doublesqueeze" => "Doublesqueeze".into(),
        "memsgd" => "Memsgd".into(),
        "liec" => "Liec".into(),
        "cser" => "Cser".into(),
        "neolithic" => "Neolithic".into(),
        "m3" => "M3".into(),
        other => other.into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    #[test]
    fn fast_table_produces_all_method_rows() {
        let mut cfg = preset("quick").unwrap();
        cfg.rounds = 3;
        cfg.n_clients = 3;
        cfg.n_is = 32;
        cfg.block_size = 64;
        let dir = std::env::temp_dir().join("bicompfl_table_test");
        let out = run_table(&cfg, MethodFilter::default(), true, &dir).unwrap();
        // 7 baselines + 6 bicompfl + 1 cfl.
        assert_eq!(out.rows.len(), 14);
        // BiCompFL rows must be far cheaper than FedAvg.
        let fedavg = out.rows.iter().find(|r| r.method == "FedAvg").unwrap();
        let gr = out
            .rows
            .iter()
            .find(|r| r.method.contains("BiCompFL-GR-Fixed"))
            .unwrap();
        assert!(
            gr.summary.bpp < fedavg.summary.bpp / 30.0,
            "GR bpp {} vs FedAvg {}",
            gr.summary.bpp,
            fedavg.summary.bpp
        );
        assert!(dir.join("quick.csv").exists());
        assert!(dir.join("quick.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn filters_restrict_method_set() {
        let mut cfg = preset("quick").unwrap();
        cfg.rounds = 2;
        cfg.n_clients = 2;
        cfg.n_is = 16;
        cfg.block_size = 64;
        let dir = std::env::temp_dir().join("bicompfl_table_filter_test");
        let out = run_table(
            &cfg,
            MethodFilter {
                baselines: false,
                bicompfl: true,
                cfl: false,
            },
            true,
            &dir,
        )
        .unwrap();
        assert_eq!(out.rows.len(), 6);
        std::fs::remove_dir_all(&dir).ok();
    }
}
