//! Experiment drivers: every table and figure in the paper's evaluation maps
//! to a function here (DESIGN.md §5 for the index).
//!
//! * [`tables`]    — Tables 5–12 and Figures 1–11: all methods on one
//!   (dataset, arch, allocation) setting; per-round CSV (the figures) plus a
//!   summary table (the tables / Fig. 2 scatter points).
//! * [`ablations`] — Figures 12–17 and Appendix J: sweeps over n, n_DL,
//!   n_IS, block size, and the λ prior mix.
//!
//! Every driver can run against the PJRT artifact oracle (real model, the
//! recorded results) or the synthetic oracle (`fast=true`; exercises the
//! identical coordinator/compression code with a closed-form Layer 2, for
//! CI and quick iteration).

pub mod tables;
pub mod ablations;

use anyhow::{anyhow, Result};

use crate::config::ExpConfig;
use crate::coordinator::bicompfl::{BiCompFl, BiCompFlConfig};
use crate::coordinator::{MaskOracle, SyntheticMaskOracle};
use crate::data::{dirichlet_partition, iid_partition, Dataset, SynthSpec};
use crate::runtime::manifest::default_dir;
use crate::runtime::{Manifest, RuntimeOracle};

/// Build the artifact-backed oracle for an experiment config.
pub fn build_runtime_oracle(cfg: &ExpConfig) -> Result<RuntimeOracle> {
    let manifest = Manifest::load(&default_dir())?;
    manifest.check()?;
    let spec = SynthSpec::by_name(&cfg.dataset)
        .ok_or_else(|| anyhow!("unknown dataset {}", cfg.dataset))?;
    let (train, test) = Dataset::generate(&spec);
    let alloc = if cfg.iid {
        iid_partition(&train, cfg.n_clients, cfg.seed ^ 0xA110C)
    } else {
        dirichlet_partition(&train, cfg.n_clients, cfg.dirichlet_alpha, cfg.seed ^ 0xA110C)
    };
    RuntimeOracle::new(
        &manifest,
        &cfg.arch,
        train,
        test,
        alloc.client_indices,
        cfg.seed,
    )
}

/// Build the fast synthetic oracle matching the experiment's shape. The
/// dimension mirrors the arch when artifacts exist, else a fixed small d.
pub fn build_synthetic_oracle(cfg: &ExpConfig) -> SyntheticMaskOracle {
    let d = Manifest::load(&default_dir())
        .ok()
        .and_then(|m| m.arch(&cfg.arch).map(|a| a.d.min(4096)))
        .unwrap_or(1024);
    let het = if cfg.iid { 0.05 } else { 0.25 };
    SyntheticMaskOracle::new(d, cfg.n_clients, cfg.seed, het)
}

/// Instantiate a BiCompFL run from an experiment config + method selection.
pub fn bicompfl_config(
    cfg: &ExpConfig,
    method: &crate::config::BiCompFlMethod,
    d_hint: usize,
) -> BiCompFlConfig {
    let b_max = (d_hint / 4).max(16).min(4096);
    BiCompFlConfig {
        variant: method.variant,
        n_is: cfg.n_is,
        n_ul: cfg.n_ul,
        n_dl: cfg.n_dl,
        allocation: method.alloc.build(cfg.n_is, cfg.block_size, b_max),
        local_iters: cfg.local_iters,
        local_lr: cfg.mask_lr,
        seed: cfg.seed,
        ..Default::default()
    }
}

/// Run one BiCompFL method against any mask oracle.
pub fn run_bicompfl(
    cfg: &ExpConfig,
    method: &crate::config::BiCompFlMethod,
    oracle: &mut dyn MaskOracle,
) -> Vec<crate::algorithms::runner::RoundRecord> {
    let d = oracle.dim();
    let n = oracle.n_clients();
    let mut alg = BiCompFl::new(d, n, bicompfl_config(cfg, method, d));
    alg.run(oracle, cfg.rounds, cfg.eval_every)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{preset, table_methods};

    #[test]
    fn synthetic_pipeline_runs_every_method() {
        let mut cfg = preset("quick").unwrap();
        cfg.rounds = 3;
        cfg.n_clients = 3;
        cfg.n_is = 32;
        cfg.block_size = 32;
        for m in table_methods() {
            let mut oracle = build_synthetic_oracle(&cfg);
            let recs = run_bicompfl(&cfg, &m, &mut oracle);
            assert_eq!(recs.len(), 3, "{}", m.label());
            assert!(recs.iter().all(|r| r.ul_bits > 0));
        }
    }
}
