//! Ablation drivers: Figures 12–17 and Appendix J.
//!
//! Each sweep runs BiCompFL while varying exactly one factor and reports
//! accuracy-vs-bits trajectories per sweep point. Sweeps run on either
//! oracle (`fast` selects the synthetic one; the recorded results use the
//! artifact oracle at the default experiment scale).

use std::path::Path;

use anyhow::Result;

use super::{bicompfl_config, build_runtime_oracle, build_synthetic_oracle};
use crate::algorithms::runner::RoundRecord;
use crate::config::{Alloc, BiCompFlMethod, ExpConfig};
use crate::coordinator::bicompfl::{BiCompFl, Variant};
use crate::coordinator::MaskOracle;
use crate::metrics::{render_table, write_summary_json, CsvLog, TableRow};

fn run_one(
    cfg: &ExpConfig,
    method: BiCompFlMethod,
    fast: bool,
    mutate: impl FnOnce(&mut crate::coordinator::bicompfl::BiCompFlConfig),
) -> Result<(usize, Vec<RoundRecord>)> {
    let run = |oracle: &mut dyn MaskOracle| {
        let d = oracle.dim();
        let mut bcfg = bicompfl_config(cfg, &method, d);
        mutate(&mut bcfg);
        let mut alg = BiCompFl::new(d, oracle.n_clients(), bcfg);
        (d, alg.run(oracle, cfg.rounds, cfg.eval_every))
    };
    Ok(if fast {
        let mut oracle = build_synthetic_oracle(cfg);
        run(&mut oracle)
    } else {
        let mut oracle = build_runtime_oracle(cfg)?;
        run(&mut oracle)
    })
}

fn sweep<T: std::fmt::Display + Copy>(
    name: &str,
    cfg: &ExpConfig,
    fast: bool,
    out_dir: &Path,
    points: &[T],
    setup: impl Fn(T, &mut ExpConfig) -> BiCompFlMethod,
    mutate: impl Fn(T, &mut crate::coordinator::bicompfl::BiCompFlConfig),
) -> Result<Vec<TableRow>> {
    let mut csv = CsvLog::create(&out_dir.join(format!("{name}.csv")))?;
    let mut rows = Vec::new();
    for &p in points {
        let mut c = cfg.clone();
        let method = setup(p, &mut c);
        let (d, recs) = run_one(&c, method, fast, |b| mutate(p, b))?;
        let label = format!("{name}={p}");
        csv.log_all(&label, &recs)?;
        rows.push(TableRow::from_records(&label, &recs, d, c.n_clients));
        crate::info!("ablation {name}: point {p} done");
    }
    write_summary_json(&out_dir.join(format!("{name}.json")), name, &rows)?;
    println!("{}", render_table(name, &rows));
    Ok(rows)
}

fn default_method() -> BiCompFlMethod {
    BiCompFlMethod {
        variant: Variant::Gr,
        alloc: Alloc::Fixed,
    }
}

/// Fig. 12/13: number of clients n ∈ {10, 30, 50} (GR and PR).
pub fn ablate_clients(cfg: &ExpConfig, fast: bool, out_dir: &Path) -> Result<Vec<TableRow>> {
    sweep(
        "ablate-clients",
        cfg,
        fast,
        out_dir,
        &[5usize, 10, 20],
        |n, c| {
            c.n_clients = n;
            default_method()
        },
        |_, _| {},
    )
}

/// Fig. 15: downlink samples n_DL ∈ {5, 10, 20} (PR).
pub fn ablate_ndl(cfg: &ExpConfig, fast: bool, out_dir: &Path) -> Result<Vec<TableRow>> {
    sweep(
        "ablate-ndl",
        cfg,
        fast,
        out_dir,
        &[5usize, 10, 20],
        |_, _| BiCompFlMethod {
            variant: Variant::Pr,
            alloc: Alloc::Fixed,
        },
        |ndl, b| b.n_dl = ndl,
    )
}

/// Fig. 16: block size ∈ {128, 256, 512} (GR-Fixed).
pub fn ablate_blocksize(cfg: &ExpConfig, fast: bool, out_dir: &Path) -> Result<Vec<TableRow>> {
    sweep(
        "ablate-blocksize",
        cfg,
        fast,
        out_dir,
        &[64usize, 128, 256],
        |bs, c| {
            c.block_size = bs;
            default_method()
        },
        |_, _| {},
    )
}

/// Fig. 17: importance samples n_IS ∈ {64, 256, 1024} (GR-Fixed).
pub fn ablate_nis(cfg: &ExpConfig, fast: bool, out_dir: &Path) -> Result<Vec<TableRow>> {
    sweep(
        "ablate-nis",
        cfg,
        fast,
        out_dir,
        &[64usize, 256, 1024],
        |nis, c| {
            c.n_is = nis;
            default_method()
        },
        |_, _| {},
    )
}

/// Fig. 14 / Appendix J.2: PR prior optimization — λ mix of the global-model
/// estimate and the previous posterior estimate.
pub fn ablate_prior(cfg: &ExpConfig, fast: bool, out_dir: &Path) -> Result<Vec<TableRow>> {
    sweep(
        "ablate-prior",
        cfg,
        fast,
        out_dir,
        &[1.0f32, 0.75, 0.5],
        |_, _| BiCompFlMethod {
            variant: Variant::Pr,
            alloc: Alloc::Fixed,
        },
        |lam, b| b.lambda = lam,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    fn quick_cfg() -> ExpConfig {
        let mut c = preset("quick").unwrap();
        c.rounds = 3;
        c.n_clients = 3;
        c.n_is = 32;
        c.block_size = 64;
        c
    }

    #[test]
    fn all_ablations_run_fast() {
        let cfg = quick_cfg();
        let dir = std::env::temp_dir().join("bicompfl_ablate_test");
        assert_eq!(ablate_clients(&cfg, true, &dir).unwrap().len(), 3);
        assert_eq!(ablate_ndl(&cfg, true, &dir).unwrap().len(), 3);
        assert_eq!(ablate_blocksize(&cfg, true, &dir).unwrap().len(), 3);
        assert_eq!(ablate_nis(&cfg, true, &dir).unwrap().len(), 3);
        assert_eq!(ablate_prior(&cfg, true, &dir).unwrap().len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn blocksize_monotone_bits() {
        // Larger blocks => fewer blocks => fewer index bits per round.
        let cfg = quick_cfg();
        let dir = std::env::temp_dir().join("bicompfl_ablate_bs_test");
        let rows = ablate_blocksize(&cfg, true, &dir).unwrap();
        assert!(rows[0].summary.ul_bpp > rows[1].summary.ul_bpp);
        assert!(rows[1].summary.ul_bpp > rows[2].summary.ul_bpp);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ndl_scales_downlink() {
        let cfg = quick_cfg();
        let dir = std::env::temp_dir().join("bicompfl_ablate_ndl_test");
        let rows = ablate_ndl(&cfg, true, &dir).unwrap();
        // n_DL = 5 -> 10 -> 20 should scale DL bits ~linearly.
        let r = rows[2].summary.dl_bpp / rows[0].summary.dl_bpp;
        assert!((r - 4.0).abs() < 0.5, "dl ratio {r}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
