"""L1 correctness: every Pallas kernel vs its pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, mask densities and value scales; explicit cases pin
the MXU-tile-aligned paths (dims divisible by 128) and the fallback paths.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pallas_kernels as pk
from compile.kernels import ref

dims = st.integers(min_value=1, max_value=40)


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    r = rng(seed)
    a = r.standard_normal((m, k), dtype=np.float32)
    b = r.standard_normal((k, n), dtype=np.float32)
    np.testing.assert_allclose(
        np.asarray(pk.matmul_pallas(a, b)),
        np.asarray(ref.matmul_ref(a, b)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_matmul_tile_aligned():
    # Exercises the tiled grid path (all dims % 128 == 0, multi-block K).
    r = rng(0)
    a = r.standard_normal((128, 256), dtype=np.float32)
    b = r.standard_normal((256, 128), dtype=np.float32)
    np.testing.assert_allclose(
        np.asarray(pk.matmul_pallas(a, b)),
        np.asarray(ref.matmul_ref(a, b)),
        rtol=1e-4,
        atol=1e-4,
    )


def test_matmul_vjp_matches_autodiff():
    r = rng(1)
    a = r.standard_normal((5, 7), dtype=np.float32)
    b = r.standard_normal((7, 3), dtype=np.float32)

    def f_pallas(a_, b_):
        return jnp.sum(jnp.sin(pk.matmul_pallas(a_, b_)))

    def f_ref(a_, b_):
        return jnp.sum(jnp.sin(ref.matmul_ref(a_, b_)))

    ga_p, gb_p = jax.grad(f_pallas, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga_p), np.asarray(ga_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb_p), np.asarray(gb_r), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# masked matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=dims,
    k=dims,
    n=dims,
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_matmul_matches_ref(m, k, n, density, seed):
    r = rng(seed)
    a = r.standard_normal((m, k), dtype=np.float32)
    w = r.standard_normal((k, n), dtype=np.float32)
    mask = (r.random((k, n)) < density).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(pk.masked_matmul(a, w, mask)),
        np.asarray(ref.masked_matmul_ref(a, w, mask)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_masked_matmul_vjp_all_cotangents():
    """dm is the straight-through path — it must match AD of the reference."""
    r = rng(2)
    a = r.standard_normal((4, 6), dtype=np.float32)
    w = r.standard_normal((6, 5), dtype=np.float32)
    m = r.random((6, 5)).astype(np.float32)  # soft mask so dm is informative

    def f(fn, a_, w_, m_):
        return jnp.sum(jnp.tanh(fn(a_, w_, m_)))

    gp = jax.grad(lambda *xs: f(pk.masked_matmul, *xs), argnums=(0, 1, 2))(a, w, m)
    gr = jax.grad(lambda *xs: f(ref.masked_matmul_ref, *xs), argnums=(0, 1, 2))(a, w, m)
    for p_, r_ in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(p_), np.asarray(r_), rtol=1e-5, atol=1e-5)


def test_masked_matmul_zero_mask_zeroes_output():
    r = rng(3)
    a = r.standard_normal((3, 8), dtype=np.float32)
    w = r.standard_normal((8, 4), dtype=np.float32)
    out = np.asarray(pk.masked_matmul(a, w, np.zeros((8, 4), np.float32)))
    np.testing.assert_array_equal(out, np.zeros((3, 4), np.float32))


# ---------------------------------------------------------------------------
# mask sampling
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 5000),
    scale=st.floats(0.1, 30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_mask_sample_matches_ref(d, scale, seed):
    r = rng(seed)
    s = (r.standard_normal(d) * scale).astype(np.float32)
    u = r.random(d, dtype=np.float32)
    np.testing.assert_array_equal(
        np.asarray(pk.mask_sample(s, u)), np.asarray(ref.mask_sample_ref(s, u))
    )


def test_mask_sample_extremes():
    # sigmoid(+40) == 1.0 => always on; sigmoid(-40) == 0 => always off.
    d = 257
    u = rng(4).random(d, dtype=np.float32)
    on = np.asarray(pk.mask_sample(np.full(d, 40.0, np.float32), u))
    off = np.asarray(pk.mask_sample(np.full(d, -40.0, np.float32), u))
    np.testing.assert_array_equal(on, np.ones(d, np.float32))
    np.testing.assert_array_equal(off, np.zeros(d, np.float32))


def test_mask_sample_statistics():
    # Empirical density ~= sigmoid(s) for constant scores.
    d = 200_000
    u = rng(5).random(d, dtype=np.float32)
    s = np.full(d, 0.8473, np.float32)  # sigmoid = 0.7
    density = float(np.asarray(pk.mask_sample(s, u)).mean())
    assert abs(density - 0.7) < 5e-3


def test_sigmoid_ref_stable():
    x = np.array([-1e4, -80, 0.0, 80, 1e4], np.float32)
    out = np.asarray(ref.sigmoid_ref(x))
    assert np.all(np.isfinite(out))
    np.testing.assert_allclose(out, [0, 0, 0.5, 1, 1], atol=1e-6)
