"""L2 correctness: architecture specs, step semantics, training sanity."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.models import (
    Arch,
    NUM_CLASSES,
    make_cfl_grad_step,
    make_eval_step,
    make_mask_train_step,
)

ARCHS = [
    ("mlp", (16, 16, 1), 1.0),
    ("lenet5", (16, 16, 1), 1.0),
    ("cnn4", (16, 16, 1), 0.25),
    ("cnn6", (16, 16, 3), 0.25),
]


def _batch(arch, b, seed=0):
    r = np.random.default_rng(seed)
    h, w, c = arch.in_shape
    x = r.standard_normal((b, h, w, c), dtype=np.float32)
    y = r.integers(0, NUM_CLASSES, size=b).astype(np.int32)
    return x, y


@pytest.mark.parametrize("name,in_shape,width", ARCHS)
def test_param_spec_contiguous(name, in_shape, width):
    arch = Arch(name, in_shape, width)
    off = 0
    for pname, shape, offset, fan_in in arch.params:
        assert offset == off, (pname, offset, off)
        assert fan_in > 0
        off += math.prod(shape)
    assert off == arch.d
    # Head always classifies into NUM_CLASSES.
    assert arch.params[-2][1][-1] == NUM_CLASSES


def test_paper_scale_param_counts():
    """Appendix F: LeNet5 61,706 / 4CNN 1,933,258 / 6CNN 2,262,602 params."""
    assert Arch("lenet5", (32, 32, 1), 1.0).d == 61706
    assert Arch("cnn4", (28, 28, 1), 1.0).d == 1933258
    assert Arch("cnn6", (32, 32, 3), 1.0).d == 2262602


@pytest.mark.parametrize("name,in_shape,width", ARCHS)
def test_forward_shapes(name, in_shape, width):
    arch = Arch(name, in_shape, width)
    r = np.random.default_rng(1)
    wf = r.standard_normal(arch.d, dtype=np.float32) * 0.1
    x, _ = _batch(arch, 3)
    logits = arch.forward(wf, x, use_pallas=False)
    assert logits.shape == (3, NUM_CLASSES)
    m = (r.random(arch.d) < 0.5).astype(np.float32)
    logits_m = arch.forward(wf, x, flat_m=m, use_pallas=False)
    assert logits_m.shape == (3, NUM_CLASSES)


def test_pallas_and_ref_forward_agree():
    arch = Arch("mlp", (16, 16, 1), 1.0)
    r = np.random.default_rng(2)
    wf = r.standard_normal(arch.d, dtype=np.float32) * 0.1
    m = (r.random(arch.d) < 0.7).astype(np.float32)
    x, _ = _batch(arch, 4)
    lp = arch.forward(wf, x, flat_m=m, use_pallas=True)
    lr = arch.forward(wf, x, flat_m=m, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), rtol=1e-4, atol=1e-4)


def test_full_mask_equals_unmasked():
    arch = Arch("lenet5", (16, 16, 1), 1.0)
    r = np.random.default_rng(3)
    wf = r.standard_normal(arch.d, dtype=np.float32) * 0.1
    x, _ = _batch(arch, 2)
    lm = arch.forward(wf, x, flat_m=np.ones(arch.d, np.float32), use_pallas=False)
    lu = arch.forward(wf, x, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lm), np.asarray(lu), rtol=1e-5, atol=1e-5)


def test_mask_train_step_moves_scores_toward_lower_loss():
    """A few STE steps on one batch must reduce loss (overfit sanity)."""
    arch = Arch("mlp", (16, 16, 1), 1.0)
    r = np.random.default_rng(4)
    # Signed-constant init (Ramanujan et al.): sign(N) * sqrt(2/fan_in).
    w = np.concatenate(
        [
            np.sign(r.standard_normal(math.prod(sh)))
            * math.sqrt(2.0 / fi)
            for (_, sh, _, fi) in arch.params
        ]
    ).astype(np.float32)
    s = np.zeros(arch.d, np.float32)  # theta = 0.5
    x, y = _batch(arch, 32, seed=5)
    step = jax.jit(make_mask_train_step(arch, use_pallas=False))
    # Fixed uniforms keep the objective deterministic so the descent is
    # monotone enough to assert on (fresh uniforms each step is the training
    # regime, but too noisy for a 30-step unit test).
    u = r.random(arch.d, dtype=np.float32)
    losses = []
    for it in range(30):
        s, loss, acc = step(s, w, u, x, y, jnp.float32(5.0))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, losses


def test_cfl_grad_matches_fd():
    """CFL gradient vs central finite differences on a few coordinates."""
    arch = Arch("mlp", (16, 16, 1), 1.0)
    r = np.random.default_rng(6)
    p = (r.standard_normal(arch.d) * 0.05).astype(np.float32)
    x, y = _batch(arch, 8, seed=7)
    step = make_cfl_grad_step(arch, use_pallas=False)
    g, loss, acc = step(p, x, y)
    g = np.asarray(g)

    from compile.models import cross_entropy

    def loss_at(pv):
        return float(cross_entropy(arch.forward(pv, x, use_pallas=False), y))

    eps = 1e-3
    idx = r.integers(0, arch.d, size=5)
    for i in idx:
        pp, pm = p.copy(), p.copy()
        pp[i] += eps
        pm[i] -= eps
        fd = (loss_at(pp) - loss_at(pm)) / (2 * eps)
        assert abs(fd - g[i]) < 5e-2 * max(1.0, abs(fd)), (i, fd, g[i])


def test_eval_step_counts_correct():
    arch = Arch("mlp", (16, 16, 1), 1.0)
    r = np.random.default_rng(8)
    w = (r.standard_normal(arch.d) * 0.1).astype(np.float32)
    x, y = _batch(arch, 16, seed=9)
    nll, correct = make_eval_step(arch, use_pallas=False)(w, x, y)
    assert nll.shape == (16,) and correct.shape == (16,)
    logits = arch.forward(w, x, use_pallas=False)
    expect = (np.argmax(np.asarray(logits), axis=-1) == y).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(correct), expect)
    assert np.all(np.asarray(nll) > 0)


def test_cfl_training_reduces_loss():
    arch = Arch("mlp", (16, 16, 1), 1.0)
    r = np.random.default_rng(10)
    p = (r.standard_normal(arch.d) * 0.05).astype(np.float32)
    x, y = _batch(arch, 32, seed=11)
    step = jax.jit(make_cfl_grad_step(arch, use_pallas=False))
    first = None
    for it in range(15):
        g, loss, acc = step(p, x, y)
        if first is None:
            first = float(loss)
        p = p - 0.5 * np.asarray(g)
    assert float(loss) < first - 0.1, (first, float(loss))
