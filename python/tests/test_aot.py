"""Manifest/artifact integrity: what aot.py wrote matches the arch specs."""

import json
import math
import os

import pytest

from compile.models import Arch

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART_DIR, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def load():
    with open(MANIFEST) as f:
        return json.load(f)


def test_artifact_files_exist():
    man = load()
    for name, art in man["artifacts"].items():
        path = os.path.join(ART_DIR, art["file"])
        assert os.path.exists(path), path
        assert os.path.getsize(path) > 100


def test_arch_entries_match_specs():
    man = load()
    for name, entry in man["archs"].items():
        arch = Arch(name, tuple(entry["in_shape"]), entry["width"])
        assert arch.d == entry["d"]
        assert len(arch.params) == len(entry["params"])
        for (pn, sh, off, fi), rec in zip(arch.params, entry["params"]):
            assert rec["name"] == pn
            assert tuple(rec["shape"]) == sh
            assert rec["offset"] == off
            assert rec["fan_in"] == fi


def test_step_shapes_consistent():
    man = load()
    bt, be = man["train_batch"], man["eval_batch"]
    for name, entry in man["archs"].items():
        d = entry["d"]
        h, w, c = entry["in_shape"]
        mt = man["artifacts"][f"{name}_mask_train"]
        assert [i["shape"] for i in mt["inputs"]] == [
            [d],
            [d],
            [d],
            [bt, h, w, c],
            [bt],
            [],
        ]
        assert [o["shape"] for o in mt["outputs"]] == [[d], [], []]
        ev = man["artifacts"][f"{name}_eval"]
        assert [o["shape"] for o in ev["outputs"]] == [[be], [be]]
        cg = man["artifacts"][f"{name}_cfl_grad"]
        assert [o["shape"] for o in cg["outputs"]] == [[d], [], []]


def test_hlo_is_text_not_proto():
    """The interchange must be HLO text (xla_extension 0.5.1 gotcha)."""
    man = load()
    any_file = os.path.join(ART_DIR, man["artifacts"]["smoke"]["file"])
    with open(any_file, "rb") as f:
        head = f.read(64)
    assert b"HloModule" in head
