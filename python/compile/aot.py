"""AOT lowering: JAX step functions -> HLO text artifacts + manifest.json.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust `xla` crate) rejects; the text parser reassigns
ids so text round-trips cleanly. Lowered with return_tuple=True; the Rust
runtime unwraps the tuple.

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.

Usage:  python -m compile.aot --out-dir ../artifacts [--paper-scale]
        [--archs mlp,lenet5,cnn4,cnn6] [--train-batch 64] [--eval-batch 256]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as catalogue
from .models import Arch


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_entry(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_artifact(fn, in_specs, name, out_dir):
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    out_avals = jax.eval_shape(fn, *in_specs)
    if not isinstance(out_avals, (tuple, list)):
        out_avals = (out_avals,)
    return {
        "file": fname,
        "inputs": [_shape_entry(s) for s in in_specs],
        "outputs": [_shape_entry(s) for s in out_avals],
    }


def smoke_fn(x, y):
    """Tiny artifact used by runtime unit tests: matmul(x, y) + 2."""
    return (jnp.matmul(x, y) + 2.0,)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--archs", default="mlp,lenet5,cnn4,cnn6")
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--train-batch", type=int, default=catalogue.TRAIN_BATCH)
    ap.add_argument("--eval-batch", type=int, default=catalogue.EVAL_BATCH)
    ap.add_argument("--no-pallas", action="store_true", help="debug: lower ref path")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    wanted = set(args.archs.split(","))
    table = catalogue.PAPER_ARCHS if args.paper_scale else catalogue.DEFAULT_ARCHS
    use_pallas = not args.no_pallas

    manifest = {
        "format": 1,
        "train_batch": args.train_batch,
        "eval_batch": args.eval_batch,
        "paper_scale": bool(args.paper_scale),
        "archs": {},
        "artifacts": {},
    }

    # Smoke artifact (runtime unit tests).
    s22 = _spec((2, 2))
    manifest["artifacts"]["smoke"] = lower_artifact(
        smoke_fn, [s22, s22], "smoke", args.out_dir
    )

    for name, in_shape, width in table:
        if name not in wanted:
            continue
        arch = Arch(name, in_shape, width)
        h, w, c = arch.in_shape
        bt, be = args.train_batch, args.eval_batch
        d = arch.d
        print(f"[aot] {name}: d={d} in_shape={arch.in_shape} width={width}")

        manifest["archs"][name] = {
            "d": d,
            "in_shape": list(arch.in_shape),
            "width": width,
            "params": [
                {"name": pn, "shape": list(sh), "offset": off, "fan_in": fi}
                for (pn, sh, off, fi) in arch.params
            ],
        }

        steps = {
            "mask_train": (
                catalogue.make_mask_train_step(arch, use_pallas),
                [
                    _spec((d,)),
                    _spec((d,)),
                    _spec((d,)),
                    _spec((bt, h, w, c)),
                    _spec((bt,), jnp.int32),
                    _spec(()),
                ],
            ),
            "cfl_grad": (
                catalogue.make_cfl_grad_step(arch, use_pallas),
                [
                    _spec((d,)),
                    _spec((bt, h, w, c)),
                    _spec((bt,), jnp.int32),
                ],
            ),
            "eval": (
                catalogue.make_eval_step(arch, use_pallas),
                [
                    _spec((d,)),
                    _spec((be, h, w, c)),
                    _spec((be,), jnp.int32),
                ],
            ),
        }
        for step_name, (fn, in_specs) in steps.items():
            art_name = f"{name}_{step_name}"
            manifest["artifacts"][art_name] = lower_artifact(
                fn, in_specs, art_name, args.out_dir
            )
            print(f"[aot]   wrote {art_name}.hlo.txt")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] manifest: {len(manifest['artifacts'])} artifacts -> {args.out_dir}")


if __name__ == "__main__":
    main()
