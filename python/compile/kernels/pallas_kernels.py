"""Layer-1 Pallas kernels: the compute hot-spots of BiCompFL's model steps.

The paper's workload is federated probabilistic mask training (FedPM-style):
every forward/backward is dominated by *masked* dense contractions
``a @ (w * m)`` plus the elementwise Bernoulli mask sampling ``1{u < sigma(s)}``.
These are written as Pallas kernels so the mask product fuses into the matmul
tile loop (on TPU the mask never round-trips through HBM) and the HBM->VMEM
schedule is explicit via ``BlockSpec``.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper ran on
CUDA GPUs; instead of porting threadblock logic we tile for the MXU —
128x128x128 f32 tiles (a/w/acc resident in VMEM, ~192 KiB << 16 MiB), grid
over (M/bm, N/bn, K/bk) with accumulation in the output ref across the K grid
dimension.

On this image Pallas MUST run ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom-calls); interpret-mode lowering inlines the kernel into
plain HLO so the resulting artifact runs anywhere, numerics identical.

Autodiff: ``pallas_call`` has no automatic VJP, so the matmul kernels are
wrapped in ``jax.custom_vjp`` with backward passes that are themselves Pallas
matmul kernels. The mask-sampling kernel is non-differentiable by design (the
straight-through estimator lives in L2).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile edge targeted at the MXU systolic array. Dimensions not divisible by
# the tile collapse to a single block along that axis (small model fallback);
# production shapes should be padded to multiples of 128.
TILE = 128

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.


def _block(dim: int) -> int:
    """Largest allowed tile for a dimension: TILE when divisible, else dim."""
    return TILE if dim % TILE == 0 else dim


# ---------------------------------------------------------------------------
# Plain matmul kernel (used standalone and as the VJP workhorse).
# ---------------------------------------------------------------------------


def _matmul_kernel(a_ref, b_ref, o_ref):
    # Accumulate over the K grid dimension; zero the tile on the first step.
    @pl.when(pl.program_id(2) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_impl(a, b):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = _block(m), _block(k), _block(n)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(a, b)


@jax.custom_vjp
def matmul_pallas(a, b):
    """``a @ b`` via a tiled Pallas kernel; f32 in/out, Pallas VJP."""
    return _matmul_impl(a, b)


def _matmul_fwd(a, b):
    return _matmul_impl(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    # da = g @ b^T ; db = a^T @ g — both as Pallas contractions.
    return _matmul_impl(g, jnp.transpose(b)), _matmul_impl(jnp.transpose(a), g)


matmul_pallas.defvjp(_matmul_fwd, _matmul_bwd)


# ---------------------------------------------------------------------------
# Masked matmul: a @ (w * m), the hot-spot of probabilistic mask training.
# ---------------------------------------------------------------------------


def _masked_matmul_kernel(a_ref, w_ref, m_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    # The mask product happens on the VMEM-resident tile: fused epilogue-free
    # contraction, no HBM traffic for (w * m).
    o_ref[...] += jnp.dot(
        a_ref[...], w_ref[...] * m_ref[...], preferred_element_type=jnp.float32
    )


def _masked_matmul_fwd_impl(a, w, m):
    mm, k = a.shape
    k2, n = w.shape
    assert k == k2 and w.shape == m.shape, (a.shape, w.shape, m.shape)
    bm, bk, bn = _block(mm), _block(k), _block(n)
    grid = (mm // bm, n // bn, k // bk)
    return pl.pallas_call(
        _masked_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, n), jnp.float32),
        interpret=INTERPRET,
    )(a, w, m)


@jax.custom_vjp
def masked_matmul(a, w, m):
    """``a @ (w * m)`` with a Pallas forward and Pallas backward.

    Cotangents: ``da = g @ (w*m)^T``, ``dw = (a^T @ g) * m``,
    ``dm = (a^T @ g) * w``. ``dm`` is what carries the straight-through
    gradient to the Bernoulli parameters in mask training.
    """
    return _masked_matmul_fwd_impl(a, w, m)


def _masked_matmul_fwd(a, w, m):
    return _masked_matmul_fwd_impl(a, w, m), (a, w, m)


def _masked_matmul_bwd(res, g):
    a, w, m = res
    wm_t = jnp.transpose(w * m)
    da = matmul_pallas(g, wm_t)
    atg = matmul_pallas(jnp.transpose(a), g)
    return da, atg * m, atg * w


masked_matmul.defvjp(_masked_matmul_fwd, _masked_matmul_bwd)


# ---------------------------------------------------------------------------
# Elementwise Bernoulli mask sampling: 1{u < sigmoid(s)}.
# ---------------------------------------------------------------------------


def _mask_sample_kernel(s_ref, u_ref, o_ref):
    s = s_ref[...]
    # Stable logistic: exp on the negative branch only.
    theta = jnp.where(
        s >= 0.0, 1.0 / (1.0 + jnp.exp(-s)), jnp.exp(s) / (1.0 + jnp.exp(s))
    )
    o_ref[...] = (u_ref[...] < theta).astype(jnp.float32)


def mask_sample(scores, u):
    """Hard Bernoulli mask over a flat vector; non-differentiable by design.

    The caller wraps this in ``stop_gradient`` and applies the STE in L2.
    Uniforms ``u`` come from the Rust coordinator (deterministic replay).
    """
    (d,) = scores.shape
    bd = TILE * TILE if d % (TILE * TILE) == 0 else d
    return pl.pallas_call(
        _mask_sample_kernel,
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((bd,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=INTERPRET,
    )(scores, u)
