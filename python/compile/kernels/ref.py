"""Pure-jnp reference oracles for the Pallas kernels (Layer-1 correctness).

Every Pallas kernel in this package has an exact counterpart here; pytest
(+ hypothesis) asserts allclose between the two across shapes/dtypes/densities.
These references are also what the L2 model uses when `use_pallas=False`
(debug path), so the oracle doubles as documentation of kernel semantics.
"""

import jax.numpy as jnp


def matmul_ref(a, b):
    """Plain matmul: a @ b with f32 accumulation."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def masked_matmul_ref(a, w, m):
    """Masked matmul: a @ (w * m).

    `m` is the (possibly straight-through-estimated) Bernoulli mask over the
    weight matrix; fusing the product into the matmul is the kernel's reason
    to exist (the mask never round-trips through HBM on TPU).
    """
    return jnp.matmul(a.astype(jnp.float32), (w * m).astype(jnp.float32))


def sigmoid_ref(x):
    """Numerically stable logistic in f32."""
    x = x.astype(jnp.float32)
    return jnp.where(x >= 0, 1.0 / (1.0 + jnp.exp(-x)), jnp.exp(x) / (1.0 + jnp.exp(x)))


def mask_sample_ref(scores, u):
    """Hard Bernoulli mask: 1{u < sigmoid(scores)} as f32.

    `u` are uniforms in [0,1) supplied by the Rust coordinator (all RNG lives
    in L3 so runs replay deterministically).
    """
    return (u < sigmoid_ref(scores)).astype(jnp.float32)
