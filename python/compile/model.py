"""Layer-2 entry point: artifact catalogue for `aot.py`.

Defines which (architecture, step) pairs get lowered, at which shapes. The
default scale is CPU-friendly (16x16 inputs, width-scaled channels); pass
`--paper-scale` to aot.py for the published dimensions (LeNet5 61,706 params /
4CNN 1,933,258 / 6CNN 2,262,602 at 28x28/32x32 inputs).
"""

from .models import Arch, make_cfl_grad_step, make_eval_step, make_mask_train_step

# (name, in_shape(H,W,C), width)
DEFAULT_ARCHS = [
    ("mlp", (16, 16, 1), 1.0),
    ("lenet5", (16, 16, 1), 1.0),
    ("cnn4", (16, 16, 1), 0.25),
    ("cnn6", (16, 16, 3), 0.25),
]

PAPER_ARCHS = [
    ("mlp", (28, 28, 1), 1.0),
    ("lenet5", (32, 32, 1), 1.0),  # classic LeNet5 takes 32x32 (padded MNIST)
    ("cnn4", (28, 28, 1), 1.0),
    ("cnn6", (32, 32, 3), 1.0),
]

TRAIN_BATCH = 64
EVAL_BATCH = 256

STEP_MAKERS = {
    "mask_train": make_mask_train_step,
    "cfl_grad": make_cfl_grad_step,
    "eval": make_eval_step,
}


def build_arch(name, in_shape, width):
    return Arch(name, in_shape, width)
