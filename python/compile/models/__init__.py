from .archs import (  # noqa: F401
    Arch,
    NUM_CLASSES,
    accuracy,
    arch_spec,
    cross_entropy,
    make_cfl_grad_step,
    make_eval_step,
    make_mask_train_step,
)
