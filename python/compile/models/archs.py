"""Model architectures (Layer 2), parameterized and flat-parameter addressed.

The Rust coordinator owns *all* state as flat f32 vectors (scores, weights,
gradients); each architecture here defines a static parameter spec — a list of
(name, shape, offset, fan_in) — that both sides agree on through the artifact
manifest. Forward passes unflatten via static slices, so the lowered HLO is a
pure function of flat vectors + batch.

Architectures follow the paper (Appendix F, Tables 2-4): LeNet5, 4CNN, 6CNN,
plus a small MLP used by the quickstart and tests. `width` scales channel and
hidden counts so the default artifacts train on CPU in minutes while
`--paper-scale` reproduces the published parameter counts.
"""

import math

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import pallas_kernels as pk
from ..kernels import ref


def _scaled(c: int, width: float) -> int:
    return max(4, int(round(c * width)))


def arch_spec(name: str, in_shape, width: float = 1.0):
    """Return the layer list for an architecture.

    Layers are tuples:
      ("conv", out_ch, ksize, padding, pool)  pool in {None, "max2", "avg2"}
      ("dense", out_features)
    The final dense(10) classifier is appended automatically.
    """
    if name == "mlp":
        return [("dense", _scaled(64, width))]
    if name == "lenet5":
        return [
            ("conv", _scaled(6, width), 5, "VALID", "avg2"),
            ("conv", _scaled(16, width), 5, "VALID", "avg2"),
            ("dense", _scaled(120, width)),
            ("dense", _scaled(84, width)),
        ]
    if name == "cnn4":
        return [
            ("conv", _scaled(64, width), 3, "SAME", None),
            ("conv", _scaled(64, width), 3, "SAME", "max2"),
            ("conv", _scaled(128, width), 3, "SAME", None),
            ("conv", _scaled(128, width), 3, "SAME", "max2"),
            ("dense", _scaled(256, width)),
            ("dense", _scaled(256, width)),
        ]
    if name == "cnn6":
        return [
            ("conv", _scaled(64, width), 3, "SAME", None),
            ("conv", _scaled(64, width), 3, "SAME", "max2"),
            ("conv", _scaled(128, width), 3, "SAME", None),
            ("conv", _scaled(128, width), 3, "SAME", "max2"),
            ("conv", _scaled(256, width), 3, "SAME", None),
            ("conv", _scaled(256, width), 3, "SAME", "max2"),
            ("dense", _scaled(256, width)),
            ("dense", _scaled(256, width)),
        ]
    raise ValueError(f"unknown arch {name!r}")


NUM_CLASSES = 10


class Arch:
    """Static description of one architecture instance (shapes fixed)."""

    def __init__(self, name: str, in_shape, width: float = 1.0):
        self.name = name
        self.in_shape = tuple(in_shape)  # (H, W, C)
        self.width = width
        self.layers = arch_spec(name, in_shape, width)
        self.params = []  # (pname, shape, offset, fan_in)
        h, w, c = self.in_shape
        off = 0

        def add(pname, shape, fan_in):
            nonlocal off
            n = math.prod(shape)
            self.params.append((pname, tuple(shape), off, fan_in))
            off += n

        for li, layer in enumerate(self.layers):
            if layer[0] == "conv":
                _, out_ch, k, pad, pool = layer
                add(f"conv{li}_w", (k, k, c, out_ch), k * k * c)
                add(f"conv{li}_b", (out_ch,), k * k * c)
                if pad == "VALID":
                    h, w = h - k + 1, w - k + 1
                if pool is not None:
                    h, w = h // 2, w // 2
                c = out_ch
            else:
                _, units = layer
                in_f = h * w * c
                add(f"dense{li}_w", (in_f, units), in_f)
                add(f"dense{li}_b", (units,), in_f)
                h, w, c = 1, 1, units
        in_f = h * w * c
        add("head_w", (in_f, NUM_CLASSES), in_f)
        add("head_b", (NUM_CLASSES,), in_f)
        self.d = off

    def unflatten(self, flat):
        """Static-slice a flat [d] vector into the parameter dict."""
        out = {}
        for pname, shape, offset, _ in self.params:
            n = math.prod(shape)
            out[pname] = lax.slice(flat, (offset,), (offset + n,)).reshape(shape)
        return out

    # -- forward ------------------------------------------------------------

    def forward(self, flat_w, x, flat_m=None, use_pallas=True):
        """Logits for batch x [B,H,W,C] given flat weights (and optional mask).

        With `flat_m`, every parameter is masked elementwise; dense layers use
        the fused Pallas `masked_matmul` so the straight-through gradient
        flows through the kernel's `dm` cotangent.
        """
        p = self.unflatten(flat_w)
        m = self.unflatten(flat_m) if flat_m is not None else None

        def wt(name):
            return p[name] * m[name] if m is not None else p[name]

        a = x
        for li, layer in enumerate(self.layers):
            if layer[0] == "conv":
                _, out_ch, k, pad, pool = layer
                a = lax.conv_general_dilated(
                    a,
                    wt(f"conv{li}_w"),
                    window_strides=(1, 1),
                    padding=pad,
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                )
                a = a + wt(f"conv{li}_b")
                a = jax.nn.relu(a)
                if pool == "max2":
                    a = lax.reduce_window(
                        a, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
                    )
                elif pool == "avg2":
                    a = (
                        lax.reduce_window(
                            a, 0.0, lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
                        )
                        / 4.0
                    )
            else:
                li_name = f"dense{li}"
                if a.ndim > 2:
                    a = a.reshape(a.shape[0], -1)
                if m is not None:
                    mm = pk.masked_matmul if use_pallas else ref.masked_matmul_ref
                    a = mm(a, p[f"{li_name}_w"], m[f"{li_name}_w"])
                else:
                    mm = pk.matmul_pallas if use_pallas else ref.matmul_ref
                    a = mm(a, p[f"{li_name}_w"])
                a = jax.nn.relu(a + wt(f"{li_name}_b"))
        if a.ndim > 2:
            a = a.reshape(a.shape[0], -1)
        if m is not None:
            mm = pk.masked_matmul if use_pallas else ref.masked_matmul_ref
            logits = mm(a, p["head_w"], m["head_w"]) + wt("head_b")
        else:
            mm = pk.matmul_pallas if use_pallas else ref.matmul_ref
            logits = mm(a, p["head_w"]) + wt("head_b")
        return logits


def cross_entropy(logits, y):
    """Mean CE over the batch; y int32 labels."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(nll)


def accuracy(logits, y):
    return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Step functions — these are the lowered artifacts.
# ---------------------------------------------------------------------------


def make_mask_train_step(arch: Arch, use_pallas=True):
    """One local SGD iteration of probabilistic mask training (Alg. 3).

    (scores s, fixed weights w, uniforms u, batch x, labels y, lr eta)
      -> (s', loss, acc)

    Scores live in the dual (logit) space; theta = sigma(s); the hard mask is
    sampled by the Pallas kernel and made differentiable via the straight-
    through estimator m~ = m + theta - sg(theta)  (gradient w.r.t. theta is
    identity — mirror descent with a KL proximity, Appendix D/G).
    """

    def step(s, w, u, x, y, eta):
        # The hard mask is sampled outside the differentiated closure: under
        # the STE its derivative is defined to be zero, and evaluating it at
        # the linearization point s (== s_) keeps the primal identical while
        # avoiding AD through the (non-differentiable) Pallas kernel.
        sample = pk.mask_sample if use_pallas else ref.mask_sample_ref
        m_hard = lax.stop_gradient(sample(s, u))

        def loss_fn(s_):
            theta = jax.nn.sigmoid(s_)
            m_ste = m_hard + theta - lax.stop_gradient(theta)
            logits = arch.forward(w, x, flat_m=m_ste, use_pallas=use_pallas)
            loss = cross_entropy(logits, y)
            return loss, accuracy(logits, y)

        (loss, acc), g = jax.value_and_grad(loss_fn, has_aux=True)(s)
        return s - eta * g, loss, acc

    return step


def make_cfl_grad_step(arch: Arch, use_pallas=True):
    """Gradient step for conventional FL: (params, x, y) -> (grad, loss, acc)."""

    def step(params, x, y):
        def loss_fn(p_):
            logits = arch.forward(p_, x, use_pallas=use_pallas)
            return cross_entropy(logits, y), accuracy(logits, y)

        (loss, acc), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return g, loss, acc

    return step


def make_eval_step(arch: Arch, use_pallas=True):
    """Evaluation: (effective weights, x, y) -> (per-example loss, correct).

    Takes *effective* weights (w ⊙ mask for stochastic FL, raw params for
    CFL) so one artifact serves both paths; Rust sums the valid prefix of the
    per-example outputs to handle ragged final batches.
    """

    def step(w_eff, x, y):
        logits = arch.forward(w_eff, x, use_pallas=use_pallas)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
        correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        return nll, correct

    return step
