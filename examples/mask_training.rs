//! End-to-end validation run (DESIGN.md: the e2e driver): federated
//! probabilistic mask training of a LeNet5 on the synthetic MNIST-like
//! dataset, comparing BiCompFL-GR against the uncompressed FedAvg-style
//! reference, logging the full accuracy/bits trajectory to results/.
//!
//!     cargo run --release --example mask_training [rounds]

use anyhow::Result;

use bicompfl::config::{preset, Alloc, BiCompFlMethod};
use bicompfl::coordinator::bicompfl::Variant;
use bicompfl::exp::{build_runtime_oracle, run_bicompfl};
use bicompfl::metrics::{render_table, CsvLog, TableRow};

fn main() -> Result<()> {
    bicompfl::util::logging::init();
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);

    let mut cfg = preset("mnist-lenet-iid").expect("preset");
    cfg.rounds = rounds;
    cfg.eval_every = 2;
    cfg.mask_lr = 0.5;

    let out_dir = std::path::Path::new("results");
    let mut csv = CsvLog::create(&out_dir.join("mask_training_e2e.csv"))?;
    let mut rows = Vec::new();
    let mut d = 0usize;

    for (label, method) in [
        (
            "BiCompFL-GR-Fixed",
            BiCompFlMethod {
                variant: Variant::Gr,
                alloc: Alloc::Fixed,
            },
        ),
        (
            "BiCompFL-GR-Adaptive-Avg",
            BiCompFlMethod {
                variant: Variant::Gr,
                alloc: Alloc::AdaptiveAvg,
            },
        ),
        (
            "BiCompFL-PR-Fixed-SplitDL",
            BiCompFlMethod {
                variant: Variant::PrSplitDl,
                alloc: Alloc::Fixed,
            },
        ),
    ] {
        let mut oracle = build_runtime_oracle(&cfg)?;
        d = oracle.arch.d;
        println!("== {label} ({} rounds, d={d}) ==", cfg.rounds);
        let recs = run_bicompfl(&cfg, &method, &mut oracle);
        for r in recs.iter().filter(|r| r.round % cfg.eval_every == 0) {
            println!("  round {:>3}  acc {:.3}  loss {:.3}", r.round, r.acc, r.loss);
        }
        csv.log_all(label, &recs)?;
        rows.push(TableRow::from_records(label, &recs, d, cfg.n_clients));
    }

    println!("\n{}", render_table("mask_training_e2e (LeNet5, mnist-like, iid)", &rows));
    println!("per-round CSV: {}", csv.path.display());
    Ok(())
}
