//! Conventional FL comparison (§4's CFL track): BiCompFL-GR-CFL with
//! stochastic SignSGD through MRC versus the error-feedback baselines, all
//! training the same model through the PJRT gradient artifact.
//!
//!     cargo run --release --example cfl_signsgd [rounds]

use anyhow::Result;

use bicompfl::algorithms::runner::run_algorithm;
use bicompfl::algorithms::{make_baseline, BASELINE_NAMES};
use bicompfl::config::preset;
use bicompfl::coordinator::cfl::{BiCompFlCfl, CflConfig, Quantizer};
use bicompfl::exp::build_runtime_oracle;
use bicompfl::metrics::{render_table, CsvLog, TableRow};

fn main() -> Result<()> {
    bicompfl::util::logging::init();
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);

    let mut cfg = preset("quick").expect("preset");
    cfg.rounds = rounds;
    cfg.eval_every = 4;
    cfg.n_clients = 10;

    let out_dir = std::path::Path::new("results");
    let mut csv = CsvLog::create(&out_dir.join("cfl_signsgd.csv"))?;
    let mut rows = Vec::new();
    let mut d = 0usize;

    // Error-feedback baselines on the gradient artifact.
    for name in BASELINE_NAMES.iter().filter(|n| **n != "fedavg") {
        let mut oracle = build_runtime_oracle(&cfg)?;
        d = oracle.arch.d;
        let mut alg = make_baseline(name, d, cfg.n_clients, cfg.server_lr).unwrap();
        alg.set_params(&oracle.weights);
        let recs = run_algorithm(alg.as_mut(), &mut oracle, cfg.rounds, cfg.eval_every, cfg.seed);
        println!(
            "{name:<16} final acc {:.3}",
            recs.last().map(|r| r.acc).unwrap_or(0.0)
        );
        csv.log_all(name, &recs)?;
        rows.push(TableRow::from_records(name, &recs, d, cfg.n_clients));
    }

    // BiCompFL-GR-CFL: stochastic sign posterior carried by MRC, Ber(0.5)
    // prior, index-relay downlink.
    let mut oracle = build_runtime_oracle(&cfg)?;
    let mut alg = BiCompFlCfl::new(
        d,
        CflConfig {
            quantizer: Quantizer::StochasticSign,
            n_is: cfg.n_is,
            block_size: cfg.block_size,
            server_lr: cfg.cfl_server_lr,
            seed: cfg.seed,
            ..Default::default()
        },
    );
    bicompfl::algorithms::CflAlgorithm::set_params(&mut alg, &oracle.weights);
    let recs = run_algorithm(&mut alg, &mut oracle, cfg.rounds, cfg.eval_every, cfg.seed);
    println!(
        "BiCompFL-GR-CFL  final acc {:.3}",
        recs.last().map(|r| r.acc).unwrap_or(0.0)
    );
    csv.log_all("BiCompFL-GR-CFL", &recs)?;
    rows.push(TableRow::from_records("BiCompFL-GR-CFL", &recs, d, cfg.n_clients));

    println!("\n{}", render_table("cfl_signsgd (mlp, mnist-like, iid)", &rows));
    Ok(())
}
