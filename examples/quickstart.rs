//! Quickstart: train a masked MLP on the synthetic MNIST-like dataset with
//! BiCompFL-GR and print accuracy + exact communication cost per round.
//!
//! Requires artifacts: `make artifacts` (Python runs once, never again).
//!
//!     cargo run --release --example quickstart

use anyhow::Result;

use bicompfl::config::{preset, Alloc, BiCompFlMethod};
use bicompfl::coordinator::bicompfl::Variant;
use bicompfl::exp::{build_runtime_oracle, run_bicompfl};
use bicompfl::metrics::{render_table, TableRow};

fn main() -> Result<()> {
    bicompfl::util::logging::init();

    // One experiment preset = one paper table; `quick` is the smoke setting.
    let mut cfg = preset("quick").expect("preset");
    cfg.rounds = 15;
    cfg.eval_every = 1;
    cfg.n_clients = 10;
    cfg.mask_lr = 0.5;

    // BiCompFL-GR with fixed 128-entry blocks and n_IS = 256 candidates:
    // every uplink block costs log2(256) = 8 bits -> 0.0625 bpp uplink.
    let method = BiCompFlMethod {
        variant: Variant::Gr,
        alloc: Alloc::Fixed,
    };

    let mut oracle = build_runtime_oracle(&cfg)?;
    let d = oracle.arch.d;
    println!(
        "training {} (d={d}) on {} with {} clients\n",
        cfg.arch, cfg.dataset, cfg.n_clients
    );
    let recs = run_bicompfl(&cfg, &method, &mut oracle);
    for r in &recs {
        println!(
            "round {:>3}  acc {:.3}  loss {:.3}  uplink {:>8} b  downlink {:>8} b",
            r.round, r.acc, r.loss, r.ul_bits, r.dl_bits
        );
    }
    let rows = vec![TableRow::from_records(
        &method.label(),
        &recs,
        d,
        cfg.n_clients,
    )];
    println!("\n{}", render_table("quickstart", &rows));
    println!("(FedAvg would cost 64 bits/param/round on the same links.)");
    Ok(())
}
