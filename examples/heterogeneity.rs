//! Heterogeneity study (the paper's non-i.i.d. track): BiCompFL variants
//! under Dirichlet(α) data allocation for several α, reporting how
//! heterogeneity affects accuracy, communication, and the GR/PR gap.
//!
//!     cargo run --release --example heterogeneity [rounds]

use anyhow::Result;

use bicompfl::config::{preset, Alloc, BiCompFlMethod};
use bicompfl::coordinator::bicompfl::Variant;
use bicompfl::exp::{build_runtime_oracle, run_bicompfl};
use bicompfl::metrics::{render_table, CsvLog, TableRow};

fn main() -> Result<()> {
    bicompfl::util::logging::init();
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);

    let out_dir = std::path::Path::new("results");
    let mut csv = CsvLog::create(&out_dir.join("heterogeneity.csv"))?;
    let mut rows = Vec::new();

    for alpha in [100.0, 1.0, 0.1] {
        for (vname, variant) in [("GR", Variant::Gr), ("PR", Variant::Pr)] {
            let mut cfg = preset("quick").expect("preset");
            cfg.rounds = rounds;
            cfg.eval_every = 4;
            cfg.n_clients = 10;
            cfg.mask_lr = 0.5;
            cfg.iid = false;
            cfg.dirichlet_alpha = alpha;
            let method = BiCompFlMethod {
                variant,
                alloc: Alloc::Fixed,
            };
            let mut oracle = build_runtime_oracle(&cfg)?;
            let d = oracle.arch.d;
            let recs = run_bicompfl(&cfg, &method, &mut oracle);
            let label = format!("{vname}-alpha={alpha}");
            println!(
                "{label:<16} final acc {:.3}",
                recs.last().map(|r| r.acc).unwrap_or(0.0)
            );
            csv.log_all(&label, &recs)?;
            rows.push(TableRow::from_records(&label, &recs, d, cfg.n_clients));
        }
    }

    println!("\n{}", render_table("heterogeneity (mlp, Dirichlet sweep)", &rows));
    Ok(())
}
